//! Online sequence packing (one of the paper's named key optimizations:
//! "online sequence packing for fast training").
//!
//! Variable-length rollouts are packed greedily (first-fit) into fixed
//! [B, T] training batches; segment ids + per-segment positions keep the
//! attention of packed sequences independent (the train graph masks
//! cross-segment attention).
//!
//! Layout per placed sequence (stream = [BOS, prompt..., gen...]):
//! row cells [o, o+L) hold the stream; position o+i-1 is the *target
//! slot* predicting stream[i]; target slots of generated tokens carry
//! mask=1, the recorded behavior logprob, weight version, advantage,
//! per-token reward and (when the preprocessor computed one) the
//! truncated importance weight in the `is_w` lane — 1.0 everywhere
//! otherwise, so an unweighted batch is exactly the uncorrected
//! objective. Everything else is masked out — including the last cell of
//! each segment, whose prediction would cross into the next segment.
//!
//! Property-tested invariant: packing is lossless — the multiset of
//! (gen token, behavior_lp, version) triples in == out.

use crate::rl::Rollout;

/// A packed training batch, ready to become train-graph literals.
#[derive(Debug, Clone)]
pub struct TrainBatch {
    pub b: usize,
    pub t: usize,
    pub tokens: Vec<i32>,
    pub seg: Vec<i32>,
    pub pos: Vec<i32>,
    pub behavior_lp: Vec<f32>,
    pub adv: Vec<f32>,
    pub reward: Vec<f32>,
    pub mask: Vec<f32>,
    /// per-token truncated IS weight lane (1.0 = uncorrected). Only
    /// meaningful where mask = 1; `host_weighted` says whether any
    /// sequence actually carried computed weights.
    pub is_w: Vec<f32>,
    /// at least one packed sequence brought host-computed IS weights
    /// (the trainer then tells the graph to use the lane instead of
    /// recomputing on-device)
    pub host_weighted: bool,
    /// weight version per target slot (0 where mask = 0)
    pub versions: Vec<u64>,
    pub n_seqs: usize,
    pub n_gen_tokens: usize,
    pub sum_reward: f64,
    /// true when this batch closes a conventional-RL step
    pub last_of_rl_step: bool,
}

impl TrainBatch {
    pub fn mean_reward(&self) -> f64 {
        if self.n_seqs == 0 {
            0.0
        } else {
            self.sum_reward / self.n_seqs as f64
        }
    }

    /// Token-fill fraction (packed cells / capacity).
    pub fn fill(&self) -> f64 {
        self.tokens.iter().filter(|&&t| t != 0).count() as f64 / (self.b * self.t) as f64
    }
}

/// Greedy first-fit packer.
pub struct Packer {
    b: usize,
    t: usize,
    used: Vec<usize>,
    next_seg: Vec<i32>,
    batch: TrainBatch,
}

impl Packer {
    pub fn new(b: usize, t: usize) -> Self {
        Packer {
            b,
            t,
            used: vec![0; b],
            next_seg: vec![1; b],
            batch: Self::empty(b, t),
        }
    }

    fn empty(b: usize, t: usize) -> TrainBatch {
        TrainBatch {
            b,
            t,
            tokens: vec![0; b * t],
            seg: vec![0; b * t],
            pos: vec![0; b * t],
            behavior_lp: vec![0.0; b * t],
            adv: vec![0.0; b * t],
            reward: vec![0.0; b * t],
            mask: vec![0.0; b * t],
            is_w: vec![1.0; b * t],
            host_weighted: false,
            versions: vec![0; b * t],
            n_seqs: 0,
            n_gen_tokens: 0,
            sum_reward: 0.0,
            last_of_rl_step: false,
        }
    }

    pub fn n_seqs(&self) -> usize {
        self.batch.n_seqs
    }

    pub fn is_empty(&self) -> bool {
        self.batch.n_seqs == 0
    }

    /// Fraction of token cells already used.
    pub fn fill_fraction(&self) -> f64 {
        self.used.iter().sum::<usize>() as f64 / (self.b * self.t) as f64
    }

    /// Would this rollout fit anywhere right now?
    pub fn fits(&self, r: &Rollout) -> bool {
        let len = r.prompt_tokens.len() + r.gen_tokens.len();
        len <= self.t && self.used.iter().any(|&u| u + len <= self.t)
    }

    /// Place a rollout (first-fit). Returns false when it doesn't fit —
    /// flush and retry. Rollouts with no generated tokens are rejected.
    pub fn try_add(&mut self, r: &Rollout, advantage: f32) -> bool {
        self.try_add_weighted(r, advantage, None)
    }

    /// [`Packer::try_add`] with an optional per-token truncated-IS weight
    /// vector (parallel to `r.gen_tokens`) destined for the batch's
    /// `is_w` lane. `None` leaves the lane at its neutral 1.0.
    pub fn try_add_weighted(
        &mut self,
        r: &Rollout,
        advantage: f32,
        weights: Option<&[f32]>,
    ) -> bool {
        if let Some(w) = weights {
            assert_eq!(
                w.len(),
                r.gen_tokens.len(),
                "IS weight vector must parallel gen_tokens"
            );
        }
        let len = r.prompt_tokens.len() + r.gen_tokens.len();
        if r.gen_tokens.is_empty() || len > self.t {
            return false;
        }
        let Some(row) = (0..self.b).find(|&i| self.used[i] + len <= self.t) else {
            return false;
        };
        let o = row * self.t + self.used[row];
        let seg_id = self.next_seg[row];
        let bt = &mut self.batch;
        // stream cells
        let stream: Vec<i32> = r
            .prompt_tokens
            .iter()
            .chain(r.gen_tokens.iter())
            .copied()
            .collect();
        for (i, &tok) in stream.iter().enumerate() {
            bt.tokens[o + i] = tok;
            bt.seg[o + i] = seg_id;
            bt.pos[o + i] = i as i32;
        }
        // target slots of generated tokens
        let plen = r.prompt_tokens.len();
        for (j, &tok) in r.gen_tokens.iter().enumerate() {
            let _ = tok;
            let slot = o + plen + j - 1; // predicts stream[plen + j]
            bt.mask[slot] = 1.0;
            bt.behavior_lp[slot] = r.behavior_lp[j];
            bt.versions[slot] = r.token_version[j];
            bt.adv[slot] = advantage;
            bt.reward[slot] = r.reward;
            if let Some(w) = weights {
                bt.is_w[slot] = w[j];
            }
        }
        if weights.is_some() {
            bt.host_weighted = true;
        }
        self.used[row] += len;
        self.next_seg[row] += 1;
        bt.n_seqs += 1;
        bt.n_gen_tokens += r.gen_tokens.len();
        bt.sum_reward += r.reward as f64;
        true
    }

    /// Take the current batch and reset.
    pub fn flush(&mut self) -> TrainBatch {
        let b = std::mem::replace(&mut self.batch, Self::empty(self.b, self.t));
        self.used.iter_mut().for_each(|u| *u = 0);
        self.next_seg.iter_mut().for_each(|s| *s = 1);
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::FinishReason;

    fn rollout(prompt: Vec<i32>, gen: Vec<i32>, reward: f32) -> Rollout {
        let n = gen.len();
        Rollout {
            seq_id: 1,
            problem_id: 1,
            group_id: 1,
            actor_id: 0,
            prompt_tokens: prompt,
            gen_tokens: gen,
            behavior_lp: (0..n).map(|i| -0.1 * (i + 1) as f32).collect(),
            token_version: (0..n).map(|i| 10 + i as u64).collect(),
            reward,
            finish: FinishReason::Eos,
            t_start: 0.0,
            t_end: 0.0,
        }
    }

    #[test]
    fn single_sequence_layout() {
        let mut p = Packer::new(2, 16);
        let r = rollout(vec![1, 5, 6], vec![7, 8, 2], 1.0);
        assert!(p.try_add(&r, 0.5));
        let b = p.flush();
        // stream in row 0
        assert_eq!(&b.tokens[0..6], &[1, 5, 6, 7, 8, 2]);
        assert_eq!(&b.seg[0..7], &[1, 1, 1, 1, 1, 1, 0]);
        assert_eq!(&b.pos[0..6], &[0, 1, 2, 3, 4, 5]);
        // targets: gen tokens are stream[3..6], so slots 2,3,4
        assert_eq!(&b.mask[0..6], &[0.0, 0.0, 1.0, 1.0, 1.0, 0.0]);
        assert_eq!(b.behavior_lp[2], -0.1);
        assert_eq!(b.versions[4], 12);
        assert_eq!(b.adv[3], 0.5);
        assert_eq!(b.reward[4], 1.0);
        assert_eq!(b.n_seqs, 1);
        assert_eq!(b.n_gen_tokens, 3);
    }

    #[test]
    fn packs_multiple_per_row_with_fresh_segments() {
        let mut p = Packer::new(1, 16);
        let r1 = rollout(vec![1, 5], vec![7, 2], 1.0);
        let r2 = rollout(vec![1, 6], vec![8, 2], 0.0);
        assert!(p.try_add(&r1, 1.0));
        assert!(p.try_add(&r2, -1.0));
        let b = p.flush();
        assert_eq!(&b.tokens[0..8], &[1, 5, 7, 2, 1, 6, 8, 2]);
        assert_eq!(&b.seg[0..8], &[1, 1, 1, 1, 2, 2, 2, 2]);
        assert_eq!(&b.pos[0..8], &[0, 1, 2, 3, 0, 1, 2, 3]);
        // seg 1 targets at slots 1,2 ; boundary slot 3 masked 0
        assert_eq!(&b.mask[0..8], &[0.0, 1.0, 1.0, 0.0, 0.0, 1.0, 1.0, 0.0]);
        assert_eq!(b.adv[5], -1.0);
    }

    #[test]
    fn rejects_when_full_or_too_long() {
        let mut p = Packer::new(1, 8);
        let long = rollout(vec![1; 6], vec![9, 9, 9, 2], 0.0);
        assert!(!p.try_add(&long, 0.0), "10 tokens > T=8");
        let r = rollout(vec![1, 5], vec![7, 8, 2], 0.0);
        assert!(p.try_add(&r, 0.0)); // 5 cells
        let r2 = rollout(vec![1, 5], vec![7, 8, 2], 0.0);
        assert!(!p.try_add(&r2, 0.0), "only 3 cells left");
        assert!(p.fits(&rollout(vec![1], vec![2], 0.0)));
    }

    #[test]
    fn empty_gen_rejected() {
        let mut p = Packer::new(1, 8);
        assert!(!p.try_add(&rollout(vec![1, 5], vec![], 0.0), 0.0));
    }

    #[test]
    fn weight_lane_lands_on_target_slots() {
        let mut p = Packer::new(1, 16);
        let r1 = rollout(vec![1, 5], vec![7, 2], 1.0);
        let r2 = rollout(vec![1, 6], vec![8, 2], 0.0);
        assert!(p.try_add_weighted(&r1, 1.0, Some(&[0.25, 4.5])));
        assert!(p.try_add_weighted(&r2, 0.0, None));
        let b = p.flush();
        assert!(b.host_weighted, "weighted sequence marks the batch");
        // r1's targets sit at slots 1,2 (see packs_multiple_per_row test)
        assert_eq!(b.is_w[1], 0.25);
        assert_eq!(b.is_w[2], 4.5);
        // r2 (unweighted) keeps the neutral lane at its targets 5,6
        assert_eq!(b.is_w[5], 1.0);
        assert_eq!(b.is_w[6], 1.0);
        // a flushed packer starts the next batch unweighted + neutral
        assert!(p.try_add(&rollout(vec![1, 5], vec![7, 2], 0.0), 0.0));
        let b2 = p.flush();
        assert!(!b2.host_weighted);
        assert!(b2.is_w.iter().all(|&w| w == 1.0));
    }

    #[test]
    #[should_panic(expected = "parallel gen_tokens")]
    fn skewed_weight_vector_panics() {
        let mut p = Packer::new(1, 16);
        let r = rollout(vec![1, 5], vec![7, 8, 2], 0.0);
        p.try_add_weighted(&r, 0.0, Some(&[1.0]));
    }

    #[test]
    fn property_packing_is_lossless() {
        crate::testkit::check("packing lossless", 120, 0x9ac8, 48, |c| {
            let mut p = Packer::new(c.usize_in(1, 4), 32);
            let mut want: Vec<(i32, u64)> = Vec::new();
            let mut batches = Vec::new();
            for _ in 0..c.usize_in(1, 12) {
                let plen = c.usize_in(1, 6);
                let glen = c.usize_in(1, 10);
                let gen: Vec<i32> =
                    (0..glen).map(|_| 3 + c.rng.below(50) as i32).collect();
                let vers: Vec<u64> = (0..glen).map(|_| c.rng.below(9) as u64).collect();
                let mut r = rollout(vec![1; plen], gen.clone(), 0.0);
                r.token_version = vers.clone();
                if !p.try_add(&r, 0.0) {
                    if !p.is_empty() {
                        batches.push(p.flush());
                    }
                    if !p.try_add(&r, 0.0) {
                        continue; // genuinely too long — skipped, not lost
                    }
                }
                want.extend(gen.iter().copied().zip(vers));
            }
            if !p.is_empty() {
                batches.push(p.flush());
            }
            let mut got: Vec<(i32, u64)> = Vec::new();
            for b in &batches {
                for i in 0..b.tokens.len() {
                    if b.mask[i] == 1.0 {
                        // the predicted token lives one cell later
                        got.push((b.tokens[i + 1], b.versions[i]));
                    }
                }
            }
            want.sort_unstable();
            got.sort_unstable();
            if want != got {
                return Err(format!(
                    "packing lost tokens: want {} got {}",
                    want.len(),
                    got.len()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn mask_never_crosses_segments() {
        crate::testkit::check("mask slots stay in-segment", 60, 0xface, 32, |c| {
            let mut p = Packer::new(2, 24);
            for _ in 0..c.usize_in(1, 8) {
                let r = rollout(
                    vec![1; c.usize_in(1, 4)],
                    (0..c.usize_in(1, 8)).map(|_| 5).collect(),
                    0.0,
                );
                let _ = p.try_add(&r, 0.0);
            }
            let b = p.flush();
            for i in 0..b.tokens.len() {
                if b.mask[i] == 1.0 {
                    let next = i + 1;
                    if next % b.t == 0 {
                        return Err(format!("mask at row end, slot {i}"));
                    }
                    if b.seg[next] != b.seg[i] || b.seg[i] == 0 {
                        return Err(format!(
                            "target slot {i} crosses segment {} -> {}",
                            b.seg[i], b.seg[next]
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
