//! The PipelineRL coordinator — the paper's system contribution (Alg. 2,
//! Fig. 4).
//!
//! Three stages run as OS threads connected by broker topics:
//!
//! ```text
//!  actor(s) ──"rollouts"──▶ preprocessor ──"batches"──▶ trainer
//!     ▲                                                    │
//!     └──────────── weight bus (in-flight updates) ◀───────┘
//! ```
//!
//! * [`actor`] owns one generation [`crate::engine::Engine`], keeps its
//!   slots saturated at batch size H, polls the weight bus between decode
//!   steps (in-flight updates), verifies rewards, streams rollouts.
//! * [`preprocessor`] groups rollouts per prompt, computes advantages,
//!   packs sequences online into fixed training batches; in
//!   **conventional mode** it instead accumulates and shuffles a buffer
//!   of B·G samples before releasing the RL step's batches (the paper's
//!   §5 tweak).
//! * [`trainer`] runs the AOT train graph (IS-REINFORCE + fused Adam),
//!   publishes a new weight version after every optimizer step
//!   (pipeline) or per RL step (conventional), tracks loss/ESS/KL/lag.
//! * [`orchestrator`] wires everything, runs the SFT warmup (the base
//!   model stand-in), and returns a [`crate::metrics::RunReport`].
//! * [`supervisor`] makes the actor tier **elastic**: actors run under an
//!   [`supervisor::ActorPool`] that can kill, restart, add, and remove
//!   them mid-run (hot-joining the weight bus and rollout topic), and a
//!   supervisor thread replays deterministic chaos schedules
//!   ([`crate::testkit::chaos`]) for fault-tolerance testing.
//!
//! Conventional mode reproduces Alg. 1 faithfully including the batch
//! drain: actors stop admitting at the quota, *finish* every in-flight
//! sequence (the Fig 2b tail), and only then does training start.

pub mod actor;
pub mod conv;
pub mod eval;
pub mod klstudy;
pub mod orchestrator;
pub mod packing;
pub mod preprocessor;
pub mod supervisor;
pub mod trainer;
pub mod warmup;

pub use conv::ConvSync;
pub use orchestrator::{run, run_with_chaos, RunSummary};
pub use packing::{Packer, TrainBatch};
pub use preprocessor::GroupCollector;
pub use supervisor::{ActorCtx, ActorPool, SpawnFn};
