//! Double-buffered parameter-set bookkeeping for overlapped in-flight
//! weight updates.
//!
//! The engine keeps two buffer sets: the **active** set the decode graph
//! executes against, and a **shadow** set the incoming weight version is
//! staged into, a few tensors at a time, *between* decode steps. When the
//! shadow set is complete it is swapped in atomically at a step boundary
//! — decoding never observes a half-staged parameter set, and never
//! stalls for the whole transfer the way the eager path does.
//!
//! `ShadowSet` is generic over the buffer type so the swap/atomicity
//! logic is testable device-free (property tests use plain integers; the
//! engine instantiates it with staged PJRT buffers).

use anyhow::{bail, Result};

#[derive(Debug)]
pub struct ShadowSet<B> {
    active: Vec<B>,
    active_version: u64,
    shadow: Vec<B>,
    shadow_version: u64,
    /// number of buffers a complete set must hold
    expect: usize,
    staging: bool,
}

impl<B> Default for ShadowSet<B> {
    fn default() -> Self {
        Self::new()
    }
}

impl<B> ShadowSet<B> {
    /// Empty set at version 0 (nothing staged, nothing active).
    pub fn new() -> Self {
        ShadowSet {
            active: Vec::new(),
            active_version: 0,
            shadow: Vec::new(),
            shadow_version: 0,
            expect: 0,
            staging: false,
        }
    }

    /// Begin staging `version`, expecting `expect` buffers. Any partially
    /// staged shadow set is discarded (the jump-to-latest semantics: a
    /// newer publish obsoletes an in-flight transfer).
    pub fn begin(&mut self, version: u64, expect: usize) {
        self.shadow.clear();
        self.shadow_version = version;
        self.expect = expect;
        self.staging = true;
    }

    /// Stage the next buffer. Returns true when the shadow set is complete
    /// and ready to commit.
    pub fn push(&mut self, buf: B) -> Result<bool> {
        if !self.staging {
            bail!("ShadowSet::push without begin");
        }
        if self.shadow.len() >= self.expect {
            bail!("ShadowSet::push past expected size {}", self.expect);
        }
        self.shadow.push(buf);
        Ok(self.ready())
    }

    /// Buffers staged so far (also the index of the next buffer to stage).
    pub fn staged(&self) -> usize {
        self.shadow.len()
    }

    pub fn staging(&self) -> bool {
        self.staging
    }

    /// True when a complete shadow set is waiting for a commit.
    pub fn ready(&self) -> bool {
        self.staging && self.shadow.len() == self.expect
    }

    /// The version currently being staged (meaningful while `staging`).
    pub fn staging_version(&self) -> u64 {
        self.shadow_version
    }

    /// Discard any in-progress staging; the active set is untouched.
    pub fn abort(&mut self) {
        self.shadow.clear();
        self.staging = false;
    }

    /// Atomically swap the complete shadow set in as active. Returns the
    /// new active version, or None (and changes nothing) when the shadow
    /// set is not complete — a commit can never expose a partial set.
    pub fn commit(&mut self) -> Option<u64> {
        if !self.ready() {
            return None;
        }
        std::mem::swap(&mut self.active, &mut self.shadow);
        self.active_version = self.shadow_version;
        self.shadow.clear();
        self.staging = false;
        Some(self.active_version)
    }

    /// The live parameter set the decode graph executes against.
    pub fn active(&self) -> &[B] {
        &self.active
    }

    /// Mutable access to the active buffers, for in-place housekeeping on
    /// committed entries (e.g. dropping keep-alive staging sources once
    /// the copies are provably complete). The set itself — length,
    /// version, membership — is still only changed by `commit`.
    pub fn active_mut(&mut self) -> &mut [B] {
        &mut self.active
    }

    pub fn active_version(&self) -> u64 {
        self.active_version
    }

    /// Most recently staged (not yet committed) buffer, if any.
    pub fn last_staged(&self) -> Option<&B> {
        self.shadow.last()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commit_only_when_complete() {
        let mut s: ShadowSet<u32> = ShadowSet::new();
        s.begin(5, 3);
        assert!(!s.push(10).unwrap());
        assert!(!s.push(11).unwrap());
        assert_eq!(s.commit(), None, "partial set must not commit");
        assert_eq!(s.active(), &[] as &[u32], "active untouched by partial staging");
        assert!(s.push(12).unwrap());
        assert_eq!(s.commit(), Some(5));
        assert_eq!(s.active(), &[10, 11, 12]);
        assert_eq!(s.active_version(), 5);
        assert!(!s.staging());
    }

    #[test]
    fn begin_discards_partial_shadow() {
        let mut s: ShadowSet<u32> = ShadowSet::new();
        s.begin(1, 2);
        s.push(1).unwrap();
        // newer version published mid-stage: jump to latest
        s.begin(2, 2);
        assert_eq!(s.staged(), 0);
        s.push(21).unwrap();
        s.push(22).unwrap();
        assert_eq!(s.commit(), Some(2));
        assert_eq!(s.active(), &[21, 22]);
    }

    #[test]
    fn push_guards() {
        let mut s: ShadowSet<u32> = ShadowSet::new();
        assert!(s.push(1).is_err(), "push before begin");
        s.begin(1, 1);
        s.push(1).unwrap();
        assert!(s.push(2).is_err(), "push past expected size");
    }

    #[test]
    fn abort_keeps_active() {
        let mut s: ShadowSet<u32> = ShadowSet::new();
        s.begin(1, 1);
        s.push(7).unwrap();
        s.commit().unwrap();
        s.begin(2, 1);
        s.push(8).unwrap();
        s.abort();
        assert_eq!(s.commit(), None);
        assert_eq!(s.active(), &[7]);
        assert_eq!(s.active_version(), 1);
    }
}
