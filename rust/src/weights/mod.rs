//! Weight bus — the in-flight weight-update transport (paper Fig. 1b,
//! Alg. 2 lines 9–10 / 18).
//!
//! Models the paper's NCCL-broadcast process group with shared-memory
//! semantics: the trainer publishes a new *versioned* parameter set after
//! every optimizer step (`request_weight_update` in the paper's API);
//! each generation engine polls between decode steps, and on seeing a
//! newer version briefly "pauses" (an optional simulated transfer delay
//! models the real broadcast time), swaps weights, and resumes decoding
//! the in-progress sequences — KV cache retained.
//!
//! Versions are monotonically increasing optimizer-step counters; they
//! are the clock the entire lag analysis (Fig 3a/6a) is measured against.

use crate::runtime::HostTensor;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// One published parameter set.
#[derive(Debug, Clone)]
pub struct WeightVersion {
    pub version: u64,
    pub params: Arc<Vec<HostTensor>>,
}

#[derive(Debug, Default)]
struct BusInner {
    current: Option<WeightVersion>,
    /// receivers that joined the "process group"
    receivers: Vec<String>,
}

/// Shared trainer → actors weight channel.
#[derive(Debug, Clone, Default)]
pub struct WeightBus {
    inner: Arc<RwLock<BusInner>>,
    version: Arc<AtomicU64>,
    /// total bytes "transferred" (per receiver fetch) — metrics
    bytes_fetched: Arc<AtomicU64>,
    publishes: Arc<AtomicU64>,
    lock: Arc<Mutex<()>>,
    /// fault injection: milliseconds each publish sleeps before the swap
    /// (chaos-harness "bus publish delay"); 0 = healthy
    publish_delay_ms: Arc<AtomicU64>,
}

impl WeightBus {
    pub fn new() -> Self {
        Self::default()
    }

    /// Paper API `init_process_group`: register a receiver. Idempotent, so
    /// a restarted actor re-joining under the same name is a no-op — the
    /// elastic pool's hot-join path.
    pub fn init_process_group(&self, receiver: &str) {
        let mut g = self.inner.write().unwrap();
        if !g.receivers.iter().any(|r| r == receiver) {
            g.receivers.push(receiver.to_string());
        }
    }

    /// De-register a receiver (actor killed or scaled away). Unknown names
    /// are ignored so kill/crash paths can call this unconditionally.
    pub fn leave_process_group(&self, receiver: &str) {
        let mut g = self.inner.write().unwrap();
        g.receivers.retain(|r| r != receiver);
    }

    pub fn receivers(&self) -> Vec<String> {
        self.inner.read().unwrap().receivers.clone()
    }

    /// Chaos injection: every subsequent publish sleeps `ms` before
    /// swapping in the new version (models a degraded broadcast path).
    /// Pass 0 to heal.
    pub fn set_publish_delay_ms(&self, ms: u64) {
        self.publish_delay_ms.store(ms, Ordering::Relaxed);
    }

    /// Paper API `request_weight_update`: publish a new version.
    /// Returns the version number assigned.
    pub fn publish(&self, version: u64, params: Arc<Vec<HostTensor>>) -> u64 {
        let delay = self.publish_delay_ms.load(Ordering::Relaxed);
        if delay > 0 {
            std::thread::sleep(std::time::Duration::from_millis(delay));
        }
        let _g = self.lock.lock().unwrap();
        {
            let mut inner = self.inner.write().unwrap();
            inner.current = Some(WeightVersion { version, params });
        }
        self.version.store(version, Ordering::Release);
        self.publishes.fetch_add(1, Ordering::Relaxed);
        version
    }

    /// Latest published version number (cheap poll — the actor calls this
    /// between every decode step).
    pub fn latest_version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Fetch if newer than `have`. Returns None when up to date.
    pub fn fetch_if_newer(&self, have: u64) -> Option<WeightVersion> {
        if self.latest_version() <= have {
            return None;
        }
        let g = self.inner.read().unwrap();
        let cur = g.current.clone()?;
        if cur.version > have {
            let bytes: usize = cur.params.iter().map(|t| t.nbytes()).sum();
            self.bytes_fetched.fetch_add(bytes as u64, Ordering::Relaxed);
            Some(cur)
        } else {
            None
        }
    }

    pub fn bytes_fetched(&self) -> u64 {
        self.bytes_fetched.load(Ordering::Relaxed)
    }

    pub fn publishes(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(v: f32) -> Arc<Vec<HostTensor>> {
        Arc::new(vec![HostTensor::from_f32(&[2], vec![v, v])])
    }

    #[test]
    fn publish_and_fetch() {
        let bus = WeightBus::new();
        assert_eq!(bus.latest_version(), 0);
        assert!(bus.fetch_if_newer(0).is_none());
        bus.publish(1, params(1.0));
        let w = bus.fetch_if_newer(0).unwrap();
        assert_eq!(w.version, 1);
        assert!(bus.fetch_if_newer(1).is_none());
    }

    #[test]
    fn newer_version_replaces() {
        let bus = WeightBus::new();
        bus.publish(1, params(1.0));
        bus.publish(2, params(2.0));
        let w = bus.fetch_if_newer(0).unwrap();
        assert_eq!(w.version, 2);
        assert_eq!(w.params[0].f32s().unwrap()[0], 2.0);
    }

    #[test]
    fn process_group_registration() {
        let bus = WeightBus::new();
        bus.init_process_group("actor-0");
        bus.init_process_group("actor-1");
        bus.init_process_group("actor-0"); // idempotent
        assert_eq!(bus.receivers(), vec!["actor-0", "actor-1"]);
        // elastic pool: leave + hot re-join
        bus.leave_process_group("actor-0");
        bus.leave_process_group("actor-7"); // unknown: ignored
        assert_eq!(bus.receivers(), vec!["actor-1"]);
        bus.init_process_group("actor-0");
        assert_eq!(bus.receivers(), vec!["actor-1", "actor-0"]);
    }

    #[test]
    fn publish_delay_injection() {
        let bus = WeightBus::new();
        bus.set_publish_delay_ms(60);
        let t0 = std::time::Instant::now();
        bus.publish(1, params(1.0));
        assert!(t0.elapsed() >= std::time::Duration::from_millis(50));
        bus.set_publish_delay_ms(0); // heal
        bus.publish(2, params(2.0));
        assert_eq!(bus.latest_version(), 2);
    }

    #[test]
    fn transfer_bytes_accounted() {
        let bus = WeightBus::new();
        bus.publish(1, params(1.0));
        let _ = bus.fetch_if_newer(0).unwrap();
        assert_eq!(bus.bytes_fetched(), 8);
    }

    #[test]
    fn concurrent_publish_fetch() {
        let bus = WeightBus::new();
        let b2 = bus.clone();
        let pubs = std::thread::spawn(move || {
            for v in 1..=100u64 {
                b2.publish(v, params(v as f32));
            }
        });
        let b3 = bus.clone();
        let gets = std::thread::spawn(move || {
            let mut have = 0;
            let mut fetched = 0;
            while have < 100 {
                if let Some(w) = b3.fetch_if_newer(have) {
                    assert!(w.version > have, "versions move forward");
                    have = w.version;
                    fetched += 1;
                }
            }
            fetched
        });
        pubs.join().unwrap();
        assert!(gets.join().unwrap() >= 1);
    }
}
