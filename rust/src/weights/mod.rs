//! Weight bus — the in-flight weight-update transport (paper Fig. 1b,
//! Alg. 2 lines 9–10 / 18).
//!
//! Models the paper's NCCL-broadcast process group with shared-memory
//! semantics: the trainer publishes a new *versioned* parameter set after
//! every optimizer step (`request_weight_update` in the paper's API);
//! each generation engine polls between decode steps and absorbs the new
//! version — KV cache retained — by one of two paths:
//!
//! * **eager** ([`WeightBus::fetch_if_newer`] + `Engine::set_weights`):
//!   decoding stalls while the whole set is staged — the pre-overlap
//!   behavior, kept for the ablation baseline;
//! * **overlapped** ([`WeightBus::begin_fetch`] → [`WeightFetch`] chunks
//!   staged into a [`ShadowSet`] between decode steps, then an atomic
//!   swap at a step boundary): the transfer rides along with decoding and
//!   the swap itself is a pointer exchange — `minimal interruption`, the
//!   paper's in-flight update as actually deployed.
//!
//! Versions are monotonically increasing optimizer-step counters; they
//! are the clock the entire lag analysis (Fig 3a/6a) is measured against.

pub mod shadow;

pub use shadow::ShadowSet;

use crate::runtime::HostTensor;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// One published parameter set.
#[derive(Debug, Clone)]
pub struct WeightVersion {
    pub version: u64,
    pub params: Arc<Vec<HostTensor>>,
}

#[derive(Debug, Default)]
struct BusInner {
    current: Option<WeightVersion>,
    /// receivers that joined the "process group"
    receivers: Vec<String>,
}

/// Shared trainer → actors weight channel.
#[derive(Debug, Clone, Default)]
pub struct WeightBus {
    inner: Arc<RwLock<BusInner>>,
    version: Arc<AtomicU64>,
    /// total bytes "transferred" (per receiver fetch) — metrics
    bytes_fetched: Arc<AtomicU64>,
    publishes: Arc<AtomicU64>,
    lock: Arc<Mutex<()>>,
    /// fault injection: milliseconds each publish sleeps before the swap
    /// (chaos-harness "bus publish delay"); 0 = healthy
    publish_delay_ms: Arc<AtomicU64>,
}

impl WeightBus {
    pub fn new() -> Self {
        Self::default()
    }

    /// Paper API `init_process_group`: register a receiver. Idempotent, so
    /// a restarted actor re-joining under the same name is a no-op — the
    /// elastic pool's hot-join path.
    pub fn init_process_group(&self, receiver: &str) {
        let mut g = self.inner.write().unwrap();
        if !g.receivers.iter().any(|r| r == receiver) {
            g.receivers.push(receiver.to_string());
        }
    }

    /// De-register a receiver (actor killed or scaled away). Unknown names
    /// are ignored so kill/crash paths can call this unconditionally.
    pub fn leave_process_group(&self, receiver: &str) {
        let mut g = self.inner.write().unwrap();
        g.receivers.retain(|r| r != receiver);
    }

    pub fn receivers(&self) -> Vec<String> {
        self.inner.read().unwrap().receivers.clone()
    }

    /// Chaos injection: every subsequent publish sleeps `ms` before
    /// swapping in the new version (models a degraded broadcast path).
    /// Pass 0 to heal.
    pub fn set_publish_delay_ms(&self, ms: u64) {
        self.publish_delay_ms.store(ms, Ordering::Relaxed);
    }

    /// Paper API `request_weight_update`: publish a new version.
    /// Returns the version number assigned.
    pub fn publish(&self, version: u64, params: Arc<Vec<HostTensor>>) -> u64 {
        let delay = self.publish_delay_ms.load(Ordering::Relaxed);
        if delay > 0 {
            std::thread::sleep(std::time::Duration::from_millis(delay));
        }
        let _g = self.lock.lock().unwrap();
        {
            let mut inner = self.inner.write().unwrap();
            inner.current = Some(WeightVersion { version, params });
        }
        self.version.store(version, Ordering::Release);
        self.publishes.fetch_add(1, Ordering::Relaxed);
        version
    }

    /// Latest published version number (cheap poll — the actor calls this
    /// between every decode step).
    pub fn latest_version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Fetch if newer than `have`. Returns None when up to date.
    pub fn fetch_if_newer(&self, have: u64) -> Option<WeightVersion> {
        if self.latest_version() <= have {
            return None;
        }
        let g = self.inner.read().unwrap();
        let cur = g.current.clone()?;
        if cur.version > have {
            let bytes: usize = cur.params.iter().map(|t| t.nbytes()).sum();
            self.bytes_fetched.fetch_add(bytes as u64, Ordering::Relaxed);
            Some(cur)
        } else {
            None
        }
    }

    /// Incremental variant of [`fetch_if_newer`](Self::fetch_if_newer):
    /// hand back a cursor that yields the new version one *tensor chunk*
    /// at a time, so the receiver can interleave staging with decode
    /// steps (the overlapped in-flight update path). Bytes are accounted
    /// per chunk as they are pulled; a fully drained fetch costs exactly
    /// what an eager fetch would.
    pub fn begin_fetch(&self, have: u64) -> Option<WeightFetch> {
        if self.latest_version() <= have {
            return None;
        }
        let g = self.inner.read().unwrap();
        let cur = g.current.clone()?;
        if cur.version > have {
            Some(WeightFetch {
                version: cur.version,
                params: cur.params,
                next: 0,
                bytes: self.bytes_fetched.clone(),
            })
        } else {
            None
        }
    }

    pub fn bytes_fetched(&self) -> u64 {
        self.bytes_fetched.load(Ordering::Relaxed)
    }

    pub fn publishes(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }
}

/// In-progress incremental weight fetch (see [`WeightBus::begin_fetch`]).
///
/// Chunk granularity is one parameter tensor — the same unit the engine
/// stages into its shadow buffer set, and the natural sub-message of the
/// paper's NCCL broadcast (per-tensor collectives). Dropping a fetch
/// mid-way (a newer version appeared) simply stops the byte accounting at
/// the chunks actually pulled.
#[derive(Debug)]
pub struct WeightFetch {
    version: u64,
    params: Arc<Vec<HostTensor>>,
    next: usize,
    bytes: Arc<AtomicU64>,
}

impl WeightFetch {
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn remaining(&self) -> usize {
        self.params.len() - self.next
    }

    pub fn done(&self) -> bool {
        self.next >= self.params.len()
    }

    /// Pull the next tensor chunk: `(param index, tensor)`. Accounts the
    /// chunk's bytes on the bus. None once the fetch is drained.
    pub fn next_chunk(&mut self) -> Option<(usize, &HostTensor)> {
        let t = self.params.get(self.next)?;
        let i = self.next;
        self.next += 1;
        self.bytes.fetch_add(t.nbytes() as u64, Ordering::Relaxed);
        Some((i, t))
    }

    /// The full parameter set behind this fetch (the eager-path escape
    /// hatch; does not advance the cursor or account bytes).
    pub fn params(&self) -> &Arc<Vec<HostTensor>> {
        &self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(v: f32) -> Arc<Vec<HostTensor>> {
        Arc::new(vec![HostTensor::from_f32(&[2], vec![v, v])])
    }

    #[test]
    fn publish_and_fetch() {
        let bus = WeightBus::new();
        assert_eq!(bus.latest_version(), 0);
        assert!(bus.fetch_if_newer(0).is_none());
        bus.publish(1, params(1.0));
        let w = bus.fetch_if_newer(0).unwrap();
        assert_eq!(w.version, 1);
        assert!(bus.fetch_if_newer(1).is_none());
    }

    #[test]
    fn newer_version_replaces() {
        let bus = WeightBus::new();
        bus.publish(1, params(1.0));
        bus.publish(2, params(2.0));
        let w = bus.fetch_if_newer(0).unwrap();
        assert_eq!(w.version, 2);
        assert_eq!(w.params[0].f32s().unwrap()[0], 2.0);
    }

    #[test]
    fn process_group_registration() {
        let bus = WeightBus::new();
        bus.init_process_group("actor-0");
        bus.init_process_group("actor-1");
        bus.init_process_group("actor-0"); // idempotent
        assert_eq!(bus.receivers(), vec!["actor-0", "actor-1"]);
        // elastic pool: leave + hot re-join
        bus.leave_process_group("actor-0");
        bus.leave_process_group("actor-7"); // unknown: ignored
        assert_eq!(bus.receivers(), vec!["actor-1"]);
        bus.init_process_group("actor-0");
        assert_eq!(bus.receivers(), vec!["actor-1", "actor-0"]);
    }

    #[test]
    fn publish_delay_injection() {
        let bus = WeightBus::new();
        bus.set_publish_delay_ms(60);
        let t0 = std::time::Instant::now();
        bus.publish(1, params(1.0));
        assert!(t0.elapsed() >= std::time::Duration::from_millis(50));
        bus.set_publish_delay_ms(0); // heal
        bus.publish(2, params(2.0));
        assert_eq!(bus.latest_version(), 2);
    }

    #[test]
    fn transfer_bytes_accounted() {
        let bus = WeightBus::new();
        bus.publish(1, params(1.0));
        let _ = bus.fetch_if_newer(0).unwrap();
        assert_eq!(bus.bytes_fetched(), 8);
    }

    #[test]
    fn chunked_fetch_yields_tensors_in_order() {
        let bus = WeightBus::new();
        assert!(bus.begin_fetch(0).is_none(), "nothing published yet");
        bus.publish(
            3,
            Arc::new(vec![
                HostTensor::from_f32(&[2], vec![1.0, 2.0]),
                HostTensor::from_i32(&[3], vec![4, 5, 6]),
            ]),
        );
        assert!(bus.begin_fetch(3).is_none(), "already up to date");
        let mut f = bus.begin_fetch(0).unwrap();
        assert_eq!(f.version(), 3);
        assert_eq!(f.n_params(), 2);
        assert_eq!(f.remaining(), 2);
        let (i, t) = f.next_chunk().unwrap();
        assert_eq!((i, t.nbytes()), (0, 8));
        assert!(!f.done());
        let (i, t) = f.next_chunk().unwrap();
        assert_eq!((i, t.nbytes()), (1, 12));
        assert!(f.done());
        assert!(f.next_chunk().is_none());
    }

    #[test]
    fn chunked_fetch_bytes_match_eager_fetch() {
        let bus = WeightBus::new();
        bus.publish(
            1,
            Arc::new(vec![
                HostTensor::zeros_f32(&[4]),
                HostTensor::zeros_f32(&[8]),
            ]),
        );
        let mut f = bus.begin_fetch(0).unwrap();
        assert_eq!(bus.bytes_fetched(), 0, "begin_fetch itself transfers nothing");
        while f.next_chunk().is_some() {}
        let chunked = bus.bytes_fetched();
        let _ = bus.fetch_if_newer(0).unwrap();
        assert_eq!(bus.bytes_fetched(), chunked * 2, "drained fetch costs the same");
    }

    #[test]
    fn abandoned_fetch_accounts_only_pulled_chunks() {
        let bus = WeightBus::new();
        bus.publish(
            1,
            Arc::new(vec![
                HostTensor::zeros_f32(&[4]),
                HostTensor::zeros_f32(&[8]),
            ]),
        );
        let mut f = bus.begin_fetch(0).unwrap();
        let _ = f.next_chunk().unwrap(); // 16 bytes
        drop(f); // newer version appeared: transfer abandoned
        assert_eq!(bus.bytes_fetched(), 16);
    }

    #[test]
    fn concurrent_publish_fetch() {
        let bus = WeightBus::new();
        let b2 = bus.clone();
        let pubs = std::thread::spawn(move || {
            for v in 1..=100u64 {
                b2.publish(v, params(v as f32));
            }
        });
        let b3 = bus.clone();
        let gets = std::thread::spawn(move || {
            let mut have = 0;
            let mut fetched = 0;
            while have < 100 {
                if let Some(w) = b3.fetch_if_newer(have) {
                    assert!(w.version > have, "versions move forward");
                    have = w.version;
                    fetched += 1;
                }
            }
            fetched
        });
        pubs.join().unwrap();
        assert!(gets.join().unwrap() >= 1);
    }
}
