//! Minimal TOML-subset parser (offline env — no toml crate).
//!
//! Supports what the config files use: `[section.sub]` tables, `key =
//! value` with strings, integers, floats, booleans and flat arrays,
//! `#` comments. Keys are flattened to dotted paths ("trainer.lr").

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            _ => bail!("expected integer, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: malformed section {line:?}", lineno + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected key = value, got {line:?}", lineno + 1);
            };
            let key = line[..eq].trim();
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if entries.insert(full.clone(), value).is_some() {
                bail!("line {}: duplicate key {full:?}", lineno + 1);
            }
        }
        Ok(TomlDoc { entries })
    }

    /// Apply `key=value` CLI overrides on top of the parsed file.
    pub fn apply_overrides(&mut self, overrides: &[String]) -> Result<()> {
        for ov in overrides {
            let Some(eq) = ov.find('=') else {
                bail!("override {ov:?} must be key=value");
            };
            let key = ov[..eq].trim().to_string();
            let value = parse_value(ov[eq + 1..].trim())?;
            self.entries.insert(key, value);
        }
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> Result<String> {
        match self.get(key) {
            Some(v) => Ok(v.as_str()?.to_string()),
            None => Ok(default.to_string()),
        }
    }

    pub fn i64_or(&self, key: &str, default: i64) -> Result<i64> {
        match self.get(key) {
            Some(v) => v.as_i64(),
            None => Ok(default),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        let v = self.i64_or(key, default as i64)?;
        if v < 0 {
            bail!("key '{key}' must be non-negative, got {v}");
        }
        Ok(v as usize)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.as_f64(),
            None => Ok(default),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            Some(v) => v.as_bool(),
            None => Ok(default),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue> {
    if text.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = text.strip_prefix('"') {
        let Some(s) = inner.strip_suffix('"') else {
            bail!("unterminated string {text:?}");
        };
        return Ok(TomlValue::Str(s.replace("\\n", "\n").replace("\\\"", "\"")));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let Some(body) = inner.strip_suffix(']') else {
            bail!("unterminated array {text:?}");
        };
        let body = body.trim();
        if body.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = split_top_level(body)?;
        return Ok(TomlValue::Arr(
            items
                .iter()
                .map(|s| parse_value(s.trim()))
                .collect::<Result<Vec<_>>>()?,
        ));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if text.contains('.') || text.contains('e') || text.contains('E') {
        if let Ok(f) = text.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    bail!("cannot parse value {text:?}")
}

fn split_top_level(body: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut cur = String::new();
    for c in body.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            # run config
            name = "demo"
            [trainer]
            lr = 1e-3          # adam
            steps = 100
            use_value = false
            [actor]
            kinds = ["add", "sub"]
            weights = [0.5, 0.5]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("name").unwrap().as_str().unwrap(), "demo");
        assert_eq!(doc.get("trainer.lr").unwrap().as_f64().unwrap(), 1e-3);
        assert_eq!(doc.get("trainer.steps").unwrap().as_i64().unwrap(), 100);
        assert!(!doc.get("trainer.use_value").unwrap().as_bool().unwrap());
        let kinds = match doc.get("actor.kinds").unwrap() {
            TomlValue::Arr(a) => a.len(),
            _ => 0,
        };
        assert_eq!(kinds, 2);
    }

    #[test]
    fn overrides_win() {
        let mut doc = TomlDoc::parse("a = 1\n[s]\nb = 2\n").unwrap();
        doc.apply_overrides(&["s.b=9".into(), "c=\"x\"".into()]).unwrap();
        assert_eq!(doc.i64_or("s.b", 0).unwrap(), 9);
        assert_eq!(doc.str_or("c", "").unwrap(), "x");
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.f64_or("missing", 2.5).unwrap(), 2.5);
        assert!(doc.bool_or("missing", true).unwrap());
    }

    #[test]
    fn usize_rejects_negative_instead_of_wrapping() {
        let doc = TomlDoc::parse("n = -5").unwrap();
        assert!(doc.usize_or("n", 1).is_err());
        assert_eq!(doc.i64_or("n", 1).unwrap(), -5);
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("a = 1\na = 2").is_err());
        assert!(TomlDoc::parse("x = @garbage").is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = TomlDoc::parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.get("s").unwrap().as_str().unwrap(), "a#b");
    }
}
