//! Typed run configuration for the PipelineRL system.
//!
//! A `RunConfig` fully determines a training run: model variant (must
//! match an AOT artifact set), training mode, actor topology, RL
//! hyper-parameters, task curriculum and queue policies. Configs load
//! from TOML files (see configs/*.toml) with CLI `key=value` overrides,
//! and are echoed into every RunReport.
//!
//! Three training modes span the paper's freshness/efficiency axis
//! (`run.mode`):
//!
//! * `pipeline` — Algorithm 2: concurrent generation/training, weights
//!   published after **every** optimizer step (in-flight updates);
//! * `periodic` (+ `run.k`) — pipeline-style concurrency, but weights
//!   publish only every `k`-th optimizer step: a middle point that
//!   amortizes the weight-transfer pause at the cost of `k−1` extra
//!   steps of lag;
//! * `conventional` (+ `run.g`) — Algorithm 1: generate B·G sequences,
//!   then G optimizer steps behind a phase barrier.
//!
//! The `[rl]` section holds the off-policyness dial alongside the usual
//! hyper-parameters:
//!
//! * `is_correction = "none" | "truncated"` (default `"truncated"`) —
//!   whether training applies Eq. (5)'s truncated importance weights to
//!   lagged tokens. `"truncated"` is the paper's corrected objective
//!   (computed exactly on-device at train time, or taken from the
//!   preprocessor's host-side weight lane when one is wired);
//!   `"none"` trains on raw logprob gradients — the uncorrected
//!   ablation;
//! * `clip_c` — the truncation constant c (paper uses 5);
//! * `ess_floor` — alert floor in (0, 1] for the host-side ESS oracle:
//!   each optimizer step whose batch ESS falls below it increments the
//!   `ess_floor_trips` counter (0 disables). The autoscaler has its own
//!   `[autoscale] ess_floor` that *replaces* the `max_lag_steps` guard;
//! * `train_truncated = true` — admit `FinishReason::Truncated` partial
//!   rollouts as trainable group members (Truncated-PPO style) instead
//!   of discarding them.

pub mod toml;

pub use self::toml::{TomlDoc, TomlValue};

use crate::broker::Policy;
use crate::data::task::{RewardCfg, TaskKind};
use crate::rl::AdvantageMode;
use crate::sched::{AutoScaleCfg, KvLayout, PreemptPolicy, SchedPolicy};
use anyhow::{bail, Result};

/// Training mode (paper §2.2 vs §4; see the module docs for the
/// freshness/efficiency axis the three points span).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Algorithm 2: concurrent generation/training, in-flight updates.
    Pipeline,
    /// Pipeline concurrency with a periodic publish cadence: weights go
    /// out every `k`-th optimizer step (`k = 1` behaves like pipeline).
    Periodic { k: usize },
    /// Algorithm 1: generate B·G sequences, then G optimizer steps.
    Conventional { g: usize },
}

impl Mode {
    pub fn name(&self) -> String {
        match self {
            Mode::Pipeline => "pipeline".into(),
            Mode::Periodic { k } => format!("periodic_k{k}"),
            Mode::Conventional { g } => format!("conventional_g{g}"),
        }
    }
}

/// `[rl] is_correction` — how training handles off-policy (lagged)
/// tokens. See the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IsCorrection {
    /// raw logprob gradients, no reweighting (the uncorrected ablation)
    None,
    /// Eq. (5) truncated importance weights `min(c, exp(lp_pi - lp_mu))`
    Truncated,
}

impl IsCorrection {
    pub fn name(&self) -> &'static str {
        match self {
            IsCorrection::None => "none",
            IsCorrection::Truncated => "truncated",
        }
    }

    pub fn parse(s: &str) -> Option<IsCorrection> {
        match s {
            "none" => Some(IsCorrection::None),
            "truncated" => Some(IsCorrection::Truncated),
            _ => None,
        }
    }

    /// The train graph's `is_flag` scalar selecting the weight source:
    /// 0 = no correction (w ≡ 1), 1 = device-computed truncated weights,
    /// 2 = host-supplied weight lane (`TrainBatch::is_w`).
    pub fn graph_flag(&self, host_weighted: bool) -> f32 {
        match (self, host_weighted) {
            (IsCorrection::None, _) => 0.0,
            (IsCorrection::Truncated, false) => 1.0,
            (IsCorrection::Truncated, true) => 2.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TaskConfig {
    pub kinds: Vec<TaskKind>,
    pub max_operand: i64,
    /// training pool size (paper: 17k problems)
    pub pool: usize,
}

impl Default for TaskConfig {
    fn default() -> Self {
        TaskConfig {
            kinds: vec![TaskKind::Add, TaskKind::Copy],
            max_operand: 99,
            pool: 4096,
        }
    }
}

/// `[elastic]` — the fault-tolerant actor-pool supervisor (pipeline mode
/// only: conventional RL's phase barrier cannot survive actor churn).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ElasticConfig {
    /// run actors under the supervisor (restart on crash, allow resize)
    pub enabled: bool,
    /// pool size floor the supervisor will not shrink below
    pub min_actors: usize,
    /// pool size ceiling the supervisor will not grow beyond
    pub max_actors: usize,
    /// shared respawn budget: total crash restarts + floor top-ups the
    /// supervisor will perform before abandoning lost slots (a global
    /// cap so a persistent fault cannot crash-loop forever)
    pub max_restarts: usize,
    /// supervisor health/chaos polling cadence
    pub poll_ms: u64,
    /// killed/descaled actors export their in-flight sequences as
    /// portable snapshots re-enqueued to surviving actors (false restores
    /// the legacy abort-everything behavior)
    pub migrate: bool,
    /// supervisor-driven in-process trainer failover: a killed or crashed
    /// trainer restarts from the latest checkpoint manifest while the
    /// actors keep running (requires `[checkpoint] every > 0` and `dir`)
    pub trainer_failover: bool,
    /// trainer restarts the supervisor performs before giving up
    pub trainer_restarts: usize,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            enabled: false,
            min_actors: 1,
            max_actors: 8,
            max_restarts: 3,
            poll_ms: 5,
            migrate: true,
            trainer_failover: false,
            trainer_restarts: 1,
        }
    }
}

/// `[kv]` — the engine's paged KV-memory layer: block granularity, pool
/// oversubscription, block-pressure preemption and replay coalescing.
#[derive(Debug, Clone, PartialEq)]
pub struct KvConfig {
    /// KV page size in tokens (the block allocator's granularity)
    pub block_size: usize,
    /// pool oversubscription factor: the engine's block pool holds
    /// worst-case-demand / overcommit blocks. 1.0 = exact sizing (every
    /// slot can reach max_seq, the legacy configuration); 2.0 = half the
    /// blocks — admission throttles and growth hits block pressure like
    /// a full HBM, which is what lets one actor run far more concurrent
    /// long rollouts per GPU (prefix sharing + preemption absorb it)
    pub overcommit: f64,
    /// block-pressure victim rule: "none" stalls the starved slot in
    /// place (legacy), "youngest" parks the least-progressed active
    /// sequence through the snapshot path
    pub preempt: PreemptPolicy,
    /// coalesced-replay batch: pending pos>0 sequences (imports, parked
    /// preemptees) are admitted min(waiting, batch, slots) at a time so
    /// one KV replay covers the batch; 1 = legacy admit-eagerly
    pub replay_batch: usize,
    /// device-side cache layout: "dense" keeps the legacy per-slot
    /// `[L, 2, B, max_seq, H, hd]` tensor; "paged" runs the
    /// `decode_paged` graph against the block pool with per-row block
    /// tables, so the allocator's paged accounting (sharing, preemption)
    /// is realized in device memory. Dense stays the default until paged
    /// parity is proven on the target runtime.
    pub layout: KvLayout,
    /// chunked-prefill width W: prompt ingestion and KV replay feed W
    /// forced tokens per `prefill_chunk` dispatch (ceil(P/W) dispatches
    /// for a P-token prefix) instead of one decode step per token.
    /// 1 = legacy token-at-a-time (bit-for-bit identical, no chunk graph
    /// needed); W > 1 requires the artifact's `prefill_chunk` entries and
    /// must not exceed the compiled chunk width in the manifest.
    pub prefill_chunk: usize,
}

impl Default for KvConfig {
    fn default() -> Self {
        KvConfig {
            block_size: 16,
            overcommit: 1.0,
            preempt: PreemptPolicy::None,
            replay_batch: 4,
            layout: KvLayout::Dense,
            prefill_chunk: 1,
        }
    }
}

/// `[checkpoint]` — trainer state snapshots and resume.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointConfig {
    /// snapshot every N optimizer steps (0 = off)
    pub every: usize,
    /// directory for `stepNNNNN.state` files + `manifest.json`
    pub dir: Option<String>,
    /// resume source: a checkpoint dir (manifest's latest) or a state file
    pub resume_from: Option<String>,
    /// prune all but the newest K states (0 = keep everything)
    pub keep_last: usize,
    /// async-writer retries on a transient state/manifest write error
    /// before the failure surfaces (0 = fail on first error)
    pub write_retries: usize,
}

impl Default for CheckpointConfig {
    fn default() -> Self {
        CheckpointConfig {
            every: 0,
            dir: None,
            resume_from: None,
            keep_last: 0,
            write_retries: 2,
        }
    }
}

/// `[control]` — the run control plane (see `crate::control`): operator
/// commands (pause / resume / drain / rollback / stop) quiescing actors
/// through the snapshot/migration path, plus the guardrail engine that
/// watches the metrics hub and auto-triggers pause-then-rollback to the
/// latest healthy checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlConfig {
    /// wire a `RunController` + `Guardrail` into the supervisor
    pub enabled: bool,
    /// sliding-window length (points) for the reward-regression and
    /// lag-runaway checks
    pub window: usize,
    /// trip when the newest window's mean reward falls more than this
    /// fraction below the previous window's mean (0 disables)
    pub reward_drop: f64,
    /// trip when `ess_floor_trips` grows by at least this many between
    /// guardrail evaluations (0 disables)
    pub ess_trip_limit: f64,
    /// trip when the smoothed token lag exceeds this many optimizer
    /// steps (0 disables)
    pub max_lag_steps: f64,
    /// guardrail-triggered rollbacks budgeted before the fail-safe
    /// transition to `Drained`
    pub rollback_budget: usize,
    /// base backoff between bounded rollback retries (doubles per retry)
    pub retry_backoff_ms: u64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            enabled: false,
            window: 8,
            reward_drop: 0.5,
            ess_trip_limit: 0.0,
            max_lag_steps: 0.0,
            rollback_budget: 2,
            retry_backoff_ms: 50,
        }
    }
}

/// `[gateway]` — the serving front door (see `crate::gateway`): admits
/// external generation requests alongside rollouts with QoS classes,
/// per-tenant KV budgets and bounded shed-oldest-batch-first queues;
/// interactive arrivals may evict batch rollouts through the snapshot
/// park path. `enabled = false` (the default) keeps every existing run
/// bit-for-bit identical — nothing consults this section and no gateway
/// is constructed (pinned by the golden digest in tests/determinism.rs).
#[derive(Debug, Clone, PartialEq)]
pub struct GatewayConfig {
    /// wire a `Gateway` front door around the generation service
    pub enabled: bool,
    /// interactive-class share of the bounded admission buffer (entries)
    pub interactive_queue: usize,
    /// batch-class share of the bounded admission buffer (entries)
    pub batch_queue: usize,
    /// per-tenant KV budget as a fraction of the service's total blocks
    /// (the house tenant — the training run itself — is exempt)
    pub tenant_kv_frac: f64,
    /// let interactive arrivals evict batch rollouts via the snapshot
    /// park path when no slot is free (off = interactive waits in queue)
    pub preempt: bool,
    /// interactive p99 admission-to-first-token objective, in gateway
    /// ticks — consumed by the device-free acceptance scenario and
    /// `benches/gateway.rs`, not enforced at admission time
    pub slo_p99_ticks: f64,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            enabled: false,
            interactive_queue: 64,
            batch_queue: 256,
            tenant_kv_frac: 0.5,
            preempt: true,
            slo_p99_ticks: 25.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct RunConfig {
    pub variant: String,
    pub mode: Mode,
    pub n_actors: usize,
    pub seed: u64,
    /// optimizer steps of RL training
    pub rl_steps: usize,
    /// supervised warmup steps (the base-model stand-in)
    pub sft_steps: usize,
    pub lr: f64,
    pub sft_lr: f64,
    /// IS truncation constant c (paper uses 5)
    pub clip_c: f64,
    /// off-policyness correction applied to lagged tokens (`[rl]
    /// is_correction`, default truncated — the paper's objective)
    pub is_correction: IsCorrection,
    /// host-ESS alert floor in (0, 1]; steps whose batch ESS falls below
    /// it bump the `ess_floor_trips` counter (0 = off)
    pub ess_floor: f64,
    /// admit `FinishReason::Truncated` partial rollouts as trainable
    /// group members (Truncated-PPO style; default off)
    pub train_truncated: bool,
    pub advantage: AdvantageMode,
    pub vf_coef: f64,
    pub temperature: f64,
    /// rollouts sampled per prompt (group-baseline group size)
    pub group_size: usize,
    /// generation budget per sequence (<= variant max_seq - prompt)
    pub max_new_tokens: usize,
    pub task: TaskConfig,
    pub reward: RewardCfg,
    /// rollout topic capacity (actor -> preprocessor)
    pub rollout_queue: usize,
    pub rollout_policy: Policy,
    /// batch topic capacity (preprocessor -> trainer)
    pub batch_queue: usize,
    /// preprocessor: force-complete an incomplete advantage group after
    /// this many seconds (0 = never). Guards against groups stranded by
    /// ring eviction of a killed actor's Aborted members.
    pub group_timeout_s: f64,
    /// preprocessor: hard cap on incomplete groups held pending; beyond
    /// it the oldest are force-completed (0 = unbounded)
    pub max_pending_groups: usize,
    /// actor: parameter tensors staged per decode step when absorbing an
    /// in-flight weight update via the overlapped (shadow-buffer) path;
    /// 0 = eager swap (stall for the whole transfer, the pre-overlap
    /// behavior kept as an ablation baseline)
    pub weight_stage_chunk: usize,
    /// engine admission policy (`[sched] policy`): which pending sequence
    /// enters a freed decode slot. `fifo` is the legacy behavior;
    /// `longest_prefix` prioritizes migrated prefixes
    pub sched: SchedPolicy,
    /// `[kv]` — paged KV memory: block size, oversubscription,
    /// preemption and replay coalescing
    pub kv: KvConfig,
    pub checkpoint: CheckpointConfig,
    pub elastic: ElasticConfig,
    /// `[autoscale]` — supervisor-driven pool resize from live signals
    /// (requires `[elastic] enabled`, pipeline mode)
    pub autoscale: AutoScaleCfg,
    /// `[control]` — run control plane: pause/drain/rollback commands +
    /// guardrail auto-rollback (requires `[elastic] trainer_failover`)
    pub control: ControlConfig,
    /// `[gateway]` — QoS-classed serving front door: user inference and
    /// rollouts on one engine (off by default; off = bit-for-bit legacy)
    pub gateway: GatewayConfig,
    /// deterministic single-thread mode: actors and trainer are stepped
    /// round-robin by the orchestrator (useful for tests & 1-core boxes)
    pub log_every: usize,
    /// extra wall-clock to simulate per weight-update transfer (models
    /// the NCCL broadcast pause; 0 for tests)
    pub weight_transfer_ms: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            variant: "tiny".into(),
            mode: Mode::Pipeline,
            n_actors: 1,
            seed: 0,
            rl_steps: 50,
            sft_steps: 60,
            lr: 3e-4,
            sft_lr: 1e-3,
            clip_c: 5.0,
            is_correction: IsCorrection::Truncated,
            ess_floor: 0.0,
            train_truncated: false,
            advantage: AdvantageMode::Group,
            vf_coef: 0.0,
            temperature: 1.0,
            group_size: 4,
            max_new_tokens: 48,
            task: TaskConfig::default(),
            reward: RewardCfg::default(),
            rollout_queue: 256,
            rollout_policy: Policy::DropOldest,
            batch_queue: 4,
            group_timeout_s: 30.0,
            max_pending_groups: 1024,
            weight_stage_chunk: 2,
            sched: SchedPolicy::Fifo,
            kv: KvConfig::default(),
            checkpoint: CheckpointConfig::default(),
            elastic: ElasticConfig::default(),
            autoscale: AutoScaleCfg::default(),
            control: ControlConfig::default(),
            gateway: GatewayConfig::default(),
            log_every: 10,
            weight_transfer_ms: 0.0,
        }
    }
}

impl RunConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<RunConfig> {
        let d = RunConfig::default();
        let mode = match doc.str_or("run.mode", "pipeline")?.as_str() {
            "pipeline" => Mode::Pipeline,
            "periodic" => Mode::Periodic {
                k: doc.usize_or("run.k", 4)?,
            },
            "conventional" => Mode::Conventional {
                g: doc.usize_or("run.g", 8)?,
            },
            m => bail!("unknown run.mode {m:?} (pipeline | periodic | conventional)"),
        };
        let is_name = doc.str_or("rl.is_correction", d.is_correction.name())?;
        let Some(is_correction) = IsCorrection::parse(&is_name) else {
            bail!("unknown rl.is_correction {is_name:?} (none | truncated)");
        };
        let advantage = match doc.str_or("rl.advantage", "group")?.as_str() {
            "group" => AdvantageMode::Group,
            "group_norm" => AdvantageMode::GroupNormalized,
            "value" => AdvantageMode::Value,
            a => bail!("unknown rl.advantage {a:?}"),
        };
        let kinds = match doc.get("task.kinds") {
            None => d.task.kinds.clone(),
            Some(TomlValue::Arr(a)) => a
                .iter()
                .map(|v| {
                    Ok(match v.as_str()? {
                        "add" => TaskKind::Add,
                        "sub" => TaskKind::Sub,
                        "chain" => TaskKind::Chain,
                        "mul" => TaskKind::Mul,
                        "copy" => TaskKind::Copy,
                        k => bail!("unknown task kind {k:?}"),
                    })
                })
                .collect::<Result<Vec<_>>>()?,
            Some(v) => bail!("task.kinds must be an array, got {v:?}"),
        };
        let rollout_policy = match doc.str_or("queues.rollout_policy", "drop_oldest")?.as_str() {
            "drop_oldest" => Policy::DropOldest,
            "block" => Policy::Block,
            p => bail!("unknown queue policy {p:?}"),
        };
        let sched_name = doc.str_or("sched.policy", d.sched.name())?;
        let Some(sched) = SchedPolicy::parse(&sched_name) else {
            bail!("unknown sched.policy {sched_name:?} (fifo | longest_prefix)");
        };
        let preempt_name = doc.str_or("kv.preempt_policy", d.kv.preempt.name())?;
        let Some(preempt) = PreemptPolicy::parse(&preempt_name) else {
            bail!("unknown kv.preempt_policy {preempt_name:?} (none | youngest)");
        };
        let layout_name = doc.str_or("kv.layout", d.kv.layout.name())?;
        let Some(kv_layout) = KvLayout::parse(&layout_name) else {
            bail!("unknown kv.layout {layout_name:?} (dense | paged)");
        };
        let da = &d.autoscale;
        Ok(RunConfig {
            variant: doc.str_or("run.variant", &d.variant)?,
            mode,
            n_actors: doc.usize_or("run.n_actors", d.n_actors)?,
            seed: doc.i64_or("run.seed", d.seed as i64)? as u64,
            rl_steps: doc.usize_or("run.rl_steps", d.rl_steps)?,
            sft_steps: doc.usize_or("run.sft_steps", d.sft_steps)?,
            lr: doc.f64_or("rl.lr", d.lr)?,
            sft_lr: doc.f64_or("rl.sft_lr", d.sft_lr)?,
            clip_c: doc.f64_or("rl.clip_c", d.clip_c)?,
            is_correction,
            ess_floor: doc.f64_or("rl.ess_floor", d.ess_floor)?,
            train_truncated: doc.bool_or("rl.train_truncated", d.train_truncated)?,
            advantage,
            vf_coef: doc.f64_or("rl.vf_coef", d.vf_coef)?,
            temperature: doc.f64_or("rl.temperature", d.temperature)?,
            group_size: doc.usize_or("rl.group_size", d.group_size)?,
            max_new_tokens: doc.usize_or("rl.max_new_tokens", d.max_new_tokens)?,
            task: TaskConfig {
                kinds,
                max_operand: doc.i64_or("task.max_operand", d.task.max_operand)?,
                pool: doc.usize_or("task.pool", d.task.pool)?,
            },
            reward: RewardCfg {
                correct: doc.f64_or("reward.correct", 1.0)? as f32,
                incorrect: doc.f64_or("reward.incorrect", 0.0)? as f32,
                length_penalty_start: doc.f64_or("reward.length_penalty_start", 0.85)? as f32,
                length_penalty_max: doc.f64_or("reward.length_penalty_max", 0.5)? as f32,
            },
            rollout_queue: doc.usize_or("queues.rollout_capacity", d.rollout_queue)?,
            rollout_policy,
            batch_queue: doc.usize_or("queues.batch_capacity", d.batch_queue)?,
            group_timeout_s: doc.f64_or("queues.group_timeout_s", d.group_timeout_s)?,
            max_pending_groups: doc
                .usize_or("queues.max_pending_groups", d.max_pending_groups)?,
            weight_stage_chunk: doc.usize_or("run.weight_stage_chunk", d.weight_stage_chunk)?,
            sched,
            kv: KvConfig {
                block_size: doc.usize_or("kv.block_size", d.kv.block_size)?,
                overcommit: doc.f64_or("kv.overcommit", d.kv.overcommit)?,
                preempt,
                replay_batch: doc.usize_or("kv.replay_batch", d.kv.replay_batch)?,
                layout: kv_layout,
                prefill_chunk: doc.usize_or("kv.prefill_chunk", d.kv.prefill_chunk)?,
            },
            autoscale: AutoScaleCfg {
                enabled: doc.bool_or("autoscale.enabled", da.enabled)?,
                backlog_per_actor: doc
                    .f64_or("autoscale.backlog_per_actor", da.backlog_per_actor)?,
                supply_high_frac: doc
                    .f64_or("autoscale.supply_high_frac", da.supply_high_frac)?,
                up_patience: doc.usize_or("autoscale.up_patience", da.up_patience as usize)?
                    as u32,
                down_patience: doc
                    .usize_or("autoscale.down_patience", da.down_patience as usize)?
                    as u32,
                cooldown: doc.usize_or("autoscale.cooldown", da.cooldown as usize)? as u32,
                max_lag_steps: doc.f64_or("autoscale.max_lag_steps", da.max_lag_steps)?,
                ess_floor: doc.f64_or("autoscale.ess_floor", da.ess_floor)?,
                min_batch_fill: doc.f64_or("autoscale.min_batch_fill", da.min_batch_fill)?,
                eval_every_ms: doc
                    .usize_or("autoscale.eval_every_ms", da.eval_every_ms as usize)?
                    as u64,
            },
            checkpoint: CheckpointConfig {
                // `trainer.checkpoint_*` kept as legacy aliases
                every: doc.usize_or(
                    "checkpoint.every",
                    doc.usize_or("trainer.checkpoint_every", d.checkpoint.every)?,
                )?,
                dir: doc
                    .get("checkpoint.dir")
                    .or_else(|| doc.get("trainer.checkpoint_dir"))
                    .map(|v| v.as_str().map(String::from))
                    .transpose()?,
                resume_from: doc
                    .get("checkpoint.resume_from")
                    .map(|v| v.as_str().map(String::from))
                    .transpose()?,
                keep_last: doc.usize_or("checkpoint.keep_last", d.checkpoint.keep_last)?,
                write_retries: doc
                    .usize_or("checkpoint.write_retries", d.checkpoint.write_retries)?,
            },
            control: ControlConfig {
                enabled: doc.bool_or("control.enabled", d.control.enabled)?,
                window: doc.usize_or("control.window", d.control.window)?,
                reward_drop: doc.f64_or("control.reward_drop", d.control.reward_drop)?,
                ess_trip_limit: doc
                    .f64_or("control.ess_trip_limit", d.control.ess_trip_limit)?,
                max_lag_steps: doc
                    .f64_or("control.max_lag_steps", d.control.max_lag_steps)?,
                rollback_budget: doc
                    .usize_or("control.rollback_budget", d.control.rollback_budget)?,
                retry_backoff_ms: doc
                    .usize_or("control.retry_backoff_ms", d.control.retry_backoff_ms as usize)?
                    as u64,
            },
            gateway: GatewayConfig {
                enabled: doc.bool_or("gateway.enabled", d.gateway.enabled)?,
                interactive_queue: doc
                    .usize_or("gateway.interactive_queue", d.gateway.interactive_queue)?,
                batch_queue: doc.usize_or("gateway.batch_queue", d.gateway.batch_queue)?,
                tenant_kv_frac: doc
                    .f64_or("gateway.tenant_kv_frac", d.gateway.tenant_kv_frac)?,
                preempt: doc.bool_or("gateway.preempt", d.gateway.preempt)?,
                slo_p99_ticks: doc.f64_or("gateway.slo_p99_ticks", d.gateway.slo_p99_ticks)?,
            },
            elastic: ElasticConfig {
                enabled: doc.bool_or("elastic.enabled", d.elastic.enabled)?,
                min_actors: doc.usize_or("elastic.min_actors", d.elastic.min_actors)?,
                max_actors: doc.usize_or("elastic.max_actors", d.elastic.max_actors)?,
                max_restarts: doc.usize_or("elastic.max_restarts", d.elastic.max_restarts)?,
                // usize_or rejects negatives instead of wrapping
                poll_ms: doc.usize_or("elastic.poll_ms", d.elastic.poll_ms as usize)? as u64,
                migrate: doc.bool_or("elastic.migrate", d.elastic.migrate)?,
                trainer_failover: doc
                    .bool_or("elastic.trainer_failover", d.elastic.trainer_failover)?,
                trainer_restarts: doc
                    .usize_or("elastic.trainer_restarts", d.elastic.trainer_restarts)?,
            },
            log_every: doc.usize_or("run.log_every", d.log_every)?,
            weight_transfer_ms: doc.f64_or("run.weight_transfer_ms", d.weight_transfer_ms)?,
        })
    }

    /// Serialize the `[rl]` (off-policyness dial) / `[sched]` / `[kv]` /
    /// `[checkpoint]` / `[elastic]` / `[autoscale]` / `[control]` /
    /// `[gateway]` sections back to TOML
    /// text that [`RunConfig::from_doc`] parses to the same values — the
    /// round-trip contract the config property test pins (a field added
    /// to one of these sections without a serializer line here fails that
    /// test, not a production run).
    pub fn sections_to_toml(&self) -> String {
        use std::fmt::Write;
        // inverse of toml::parse_value's unescaping (quotes, newlines).
        // Lone backslashes are outside the minimal TOML subset the
        // parser supports in either direction.
        fn esc(s: &str) -> String {
            s.replace('"', "\\\"").replace('\n', "\\n")
        }
        let mut s = String::new();
        let _ = writeln!(
            s,
            "[rl]\nclip_c = {}\nis_correction = \"{}\"\ness_floor = {}\ntrain_truncated = {}",
            self.clip_c,
            self.is_correction.name(),
            self.ess_floor,
            self.train_truncated
        );
        let _ = writeln!(s, "[sched]\npolicy = \"{}\"", self.sched.name());
        let _ = writeln!(
            s,
            "[kv]\nblock_size = {}\novercommit = {}\npreempt_policy = \"{}\"\nreplay_batch = {}\nlayout = \"{}\"\nprefill_chunk = {}",
            self.kv.block_size,
            self.kv.overcommit,
            self.kv.preempt.name(),
            self.kv.replay_batch,
            self.kv.layout.name(),
            self.kv.prefill_chunk
        );
        let _ = writeln!(
            s,
            "[checkpoint]\nevery = {}\nkeep_last = {}\nwrite_retries = {}",
            self.checkpoint.every, self.checkpoint.keep_last, self.checkpoint.write_retries
        );
        if let Some(dir) = &self.checkpoint.dir {
            let _ = writeln!(s, "dir = \"{}\"", esc(dir));
        }
        if let Some(from) = &self.checkpoint.resume_from {
            let _ = writeln!(s, "resume_from = \"{}\"", esc(from));
        }
        let e = &self.elastic;
        let _ = writeln!(
            s,
            "[elastic]\nenabled = {}\nmin_actors = {}\nmax_actors = {}\nmax_restarts = {}\n\
             poll_ms = {}\nmigrate = {}\ntrainer_failover = {}\ntrainer_restarts = {}",
            e.enabled,
            e.min_actors,
            e.max_actors,
            e.max_restarts,
            e.poll_ms,
            e.migrate,
            e.trainer_failover,
            e.trainer_restarts
        );
        let a = &self.autoscale;
        let _ = writeln!(
            s,
            "[autoscale]\nenabled = {}\nbacklog_per_actor = {}\nsupply_high_frac = {}\n\
             up_patience = {}\ndown_patience = {}\ncooldown = {}\nmax_lag_steps = {}\n\
             ess_floor = {}\nmin_batch_fill = {}\neval_every_ms = {}",
            a.enabled,
            a.backlog_per_actor,
            a.supply_high_frac,
            a.up_patience,
            a.down_patience,
            a.cooldown,
            a.max_lag_steps,
            a.ess_floor,
            a.min_batch_fill,
            a.eval_every_ms
        );
        let c = &self.control;
        let _ = writeln!(
            s,
            "[control]\nenabled = {}\nwindow = {}\nreward_drop = {}\ness_trip_limit = {}\n\
             max_lag_steps = {}\nrollback_budget = {}\nretry_backoff_ms = {}",
            c.enabled,
            c.window,
            c.reward_drop,
            c.ess_trip_limit,
            c.max_lag_steps,
            c.rollback_budget,
            c.retry_backoff_ms
        );
        let g = &self.gateway;
        let _ = writeln!(
            s,
            "[gateway]\nenabled = {}\ninteractive_queue = {}\nbatch_queue = {}\n\
             tenant_kv_frac = {}\npreempt = {}\nslo_p99_ticks = {}",
            g.enabled,
            g.interactive_queue,
            g.batch_queue,
            g.tenant_kv_frac,
            g.preempt,
            g.slo_p99_ticks
        );
        s
    }

    pub fn from_file(path: &std::path::Path, overrides: &[String]) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        let mut doc = TomlDoc::parse(&text)?;
        doc.apply_overrides(overrides)?;
        Self::from_doc(&doc)
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_actors == 0 {
            bail!("need at least one actor");
        }
        if let Mode::Conventional { g } = self.mode {
            if g == 0 {
                bail!("conventional mode needs g >= 1");
            }
        }
        if let Mode::Periodic { k } = self.mode {
            if k == 0 {
                bail!("periodic mode needs k >= 1 (k = 1 is pipeline's publish cadence)");
            }
        }
        if self.group_size == 0 {
            bail!("group_size must be >= 1");
        }
        if !(0.0..=100.0).contains(&self.clip_c) || self.clip_c <= 0.0 {
            bail!("clip_c must be positive");
        }
        if !self.ess_floor.is_finite() || !(0.0..=1.0).contains(&self.ess_floor) {
            bail!("rl.ess_floor must be in [0, 1], got {}", self.ess_floor);
        }
        if self.ess_floor > 0.0 && self.is_correction == IsCorrection::None {
            bail!(
                "rl.ess_floor requires is_correction = \"truncated\": without \
                 correction every weight is 1 and the batch ESS is identically \
                 1.0, so the floor could never trip"
            );
        }
        if self.kv.block_size == 0 {
            bail!("kv.block_size must be >= 1");
        }
        if !self.kv.overcommit.is_finite() || self.kv.overcommit <= 0.0 {
            bail!("kv.overcommit must be a positive factor, got {}", self.kv.overcommit);
        }
        if self.kv.replay_batch == 0 {
            bail!("kv.replay_batch must be >= 1 (1 = admit eagerly)");
        }
        if self.kv.prefill_chunk == 0 {
            bail!("kv.prefill_chunk must be >= 1 (1 = token-at-a-time prefill)");
        }
        // overcommit > 1 with preempt = none is deliberately legal: the
        // legacy stall-in-place path is the ablation baseline the
        // preemption numbers compare against
        if self.elastic.enabled {
            if !matches!(self.mode, Mode::Pipeline) {
                bail!(
                    "elastic actor pool requires pipeline mode: conventional RL's \
                     generate/train barrier cannot survive actor churn"
                );
            }
            if self.elastic.min_actors == 0 {
                bail!("elastic.min_actors must be >= 1");
            }
            if self.elastic.max_restarts >= 256 {
                // actor group ids carry the incarnation in an 8-bit field;
                // generation 256 would alias generation 0's groups
                bail!(
                    "elastic.max_restarts must be < 256, got {}",
                    self.elastic.max_restarts
                );
            }
            if self.elastic.min_actors > self.elastic.max_actors {
                bail!(
                    "elastic.min_actors {} > elastic.max_actors {}",
                    self.elastic.min_actors,
                    self.elastic.max_actors
                );
            }
            if self.n_actors < self.elastic.min_actors
                || self.n_actors > self.elastic.max_actors
            {
                bail!(
                    "n_actors {} outside elastic bounds [{}, {}]",
                    self.n_actors,
                    self.elastic.min_actors,
                    self.elastic.max_actors
                );
            }
        }
        if self.elastic.trainer_failover {
            if !matches!(self.mode, Mode::Pipeline) {
                bail!(
                    "trainer failover requires pipeline mode: conventional RL's \
                     phase barrier cannot straddle a trainer restart"
                );
            }
            if !self.elastic.enabled {
                bail!(
                    "trainer failover requires the elastic supervisor ([elastic] \
                     enabled = true): only a supervisor-owned trainer slot can be \
                     respawned — without it the flag would silently do nothing"
                );
            }
            if self.checkpoint.every == 0 || self.checkpoint.dir.is_none() {
                bail!(
                    "trainer failover requires durable recovery points: set \
                     [checkpoint] every > 0 and [checkpoint] dir — a respawned \
                     trainer resumes from the latest manifest state"
                );
            }
            if self.elastic.trainer_restarts == 0 {
                bail!("elastic.trainer_restarts must be >= 1 when trainer_failover is on");
            }
        }
        if self.autoscale.enabled {
            if !self.elastic.enabled {
                bail!(
                    "autoscale requires the elastic actor pool ([elastic] enabled = true): \
                     only a supervised pool can be resized"
                );
            }
            if !self.elastic.migrate {
                bail!(
                    "autoscale requires [elastic] migrate = true: scale-down hands a \
                     descaled actor's in-flight sequences back through the migration \
                     hub, and the hub's depth is the scale-up backlog signal — without \
                     migration, descaling discards work and the pool can never grow"
                );
            }
            if self.autoscale.backlog_per_actor <= 0.0 {
                bail!("autoscale.backlog_per_actor must be positive");
            }
            if !(0.0..=1.0).contains(&self.autoscale.supply_high_frac)
                || self.autoscale.supply_high_frac == 0.0
            {
                bail!(
                    "autoscale.supply_high_frac must be in (0, 1], got {}",
                    self.autoscale.supply_high_frac
                );
            }
            if self.autoscale.up_patience == 0 || self.autoscale.down_patience == 0 {
                bail!("autoscale patience values must be >= 1");
            }
            if !self.autoscale.ess_floor.is_finite()
                || !(0.0..=1.0).contains(&self.autoscale.ess_floor)
            {
                bail!(
                    "autoscale.ess_floor must be in [0, 1], got {}",
                    self.autoscale.ess_floor
                );
            }
        }
        if self.control.enabled {
            if !self.elastic.trainer_failover {
                bail!(
                    "run control plane requires [elastic] trainer_failover = true: \
                     guardrail-triggered rollback restores the trainer through the \
                     supervisor's failover slot — without it a trip could only stop \
                     the run, never recover it"
                );
            }
            if self.control.window == 0 {
                bail!("control.window must be >= 1 (sliding-window length in steps)");
            }
            if !self.control.reward_drop.is_finite()
                || !(0.0..=1.0).contains(&self.control.reward_drop)
            {
                bail!(
                    "control.reward_drop must be a fraction in [0, 1] (0 disables), got {}",
                    self.control.reward_drop
                );
            }
            if !self.control.ess_trip_limit.is_finite() || self.control.ess_trip_limit < 0.0 {
                bail!(
                    "control.ess_trip_limit must be >= 0 (0 disables), got {}",
                    self.control.ess_trip_limit
                );
            }
            if !self.control.max_lag_steps.is_finite() || self.control.max_lag_steps < 0.0 {
                bail!(
                    "control.max_lag_steps must be >= 0 (0 disables), got {}",
                    self.control.max_lag_steps
                );
            }
            if self.control.rollback_budget == 0 {
                bail!(
                    "control.rollback_budget must be >= 1 when the control plane is \
                     enabled: a zero budget would turn every guardrail trip into an \
                     immediate drain, which is spelled [control] enabled = false"
                );
            }
        }
        if self.gateway.enabled {
            if self.gateway.interactive_queue == 0 || self.gateway.batch_queue == 0 {
                bail!(
                    "gateway queues must each hold at least one entry: a zero-length \
                     class queue silently rejects that whole class, which is spelled \
                     [gateway] enabled = false"
                );
            }
            if !self.gateway.tenant_kv_frac.is_finite()
                || self.gateway.tenant_kv_frac <= 0.0
                || self.gateway.tenant_kv_frac > 1.0
            {
                bail!(
                    "gateway.tenant_kv_frac must be a fraction in (0, 1], got {}",
                    self.gateway.tenant_kv_frac
                );
            }
            if !self.gateway.slo_p99_ticks.is_finite() || self.gateway.slo_p99_ticks <= 0.0 {
                bail!(
                    "gateway.slo_p99_ticks must be a positive tick count, got {}",
                    self.gateway.slo_p99_ticks
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let doc = TomlDoc::parse(
            r#"
            [run]
            variant = "small"
            mode = "conventional"
            g = 16
            n_actors = 2
            rl_steps = 100
            [rl]
            lr = 5e-4
            clip_c = 5.0
            advantage = "group_norm"
            [task]
            kinds = ["add", "chain"]
            max_operand = 999
            [queues]
            rollout_policy = "block"
            "#,
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.variant, "small");
        assert_eq!(cfg.mode, Mode::Conventional { g: 16 });
        assert_eq!(cfg.advantage, AdvantageMode::GroupNormalized);
        assert_eq!(cfg.task.kinds, vec![TaskKind::Add, TaskKind::Chain]);
        assert_eq!(cfg.rollout_policy, crate::broker::Policy::Block);
        cfg.validate().unwrap();
    }

    #[test]
    fn parses_elastic_and_checkpoint_sections() {
        let doc = TomlDoc::parse(
            r#"
            [run]
            n_actors = 2
            [elastic]
            enabled = true
            min_actors = 1
            max_actors = 4
            max_restarts = 7
            [checkpoint]
            every = 5
            dir = "ckpts"
            resume_from = "ckpts"
            keep_last = 3
            "#,
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert!(cfg.elastic.enabled);
        assert_eq!(cfg.elastic.max_actors, 4);
        assert_eq!(cfg.elastic.max_restarts, 7);
        assert_eq!(cfg.checkpoint.every, 5);
        assert_eq!(cfg.checkpoint.dir.as_deref(), Some("ckpts"));
        assert_eq!(cfg.checkpoint.resume_from.as_deref(), Some("ckpts"));
        assert_eq!(cfg.checkpoint.keep_last, 3);
        cfg.validate().unwrap();
    }

    #[test]
    fn legacy_trainer_checkpoint_keys_still_parse() {
        let doc = TomlDoc::parse(
            "[trainer]\ncheckpoint_every = 2\ncheckpoint_dir = \"old\"",
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.checkpoint.every, 2);
        assert_eq!(cfg.checkpoint.dir.as_deref(), Some("old"));
    }

    #[test]
    fn elastic_rejects_conventional_and_bad_bounds() {
        let mut cfg = RunConfig::default();
        cfg.elastic.enabled = true;
        cfg.mode = Mode::Conventional { g: 4 };
        assert!(cfg.validate().is_err(), "elastic + conventional refused");

        let mut cfg = RunConfig::default();
        cfg.elastic.enabled = true;
        cfg.n_actors = 9; // above default max_actors = 8
        assert!(cfg.validate().is_err(), "n_actors outside elastic bounds");

        let mut cfg = RunConfig::default();
        cfg.elastic.enabled = true;
        cfg.elastic.min_actors = 5;
        cfg.elastic.max_actors = 2;
        assert!(cfg.validate().is_err(), "min > max refused");
    }

    #[test]
    fn parses_sched_and_autoscale_sections() {
        let doc = TomlDoc::parse(
            r#"
            [run]
            n_actors = 2
            [sched]
            policy = "longest_prefix"
            [elastic]
            enabled = true
            [autoscale]
            enabled = true
            backlog_per_actor = 3.5
            up_patience = 2
            cooldown = 6
            "#,
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sched, SchedPolicy::LongestPrefixFirst);
        assert!(cfg.autoscale.enabled);
        assert_eq!(cfg.autoscale.backlog_per_actor, 3.5);
        assert_eq!(cfg.autoscale.up_patience, 2);
        assert_eq!(cfg.autoscale.cooldown, 6);
        // unset keys keep defaults
        assert_eq!(cfg.autoscale.down_patience, AutoScaleCfg::default().down_patience);
        cfg.validate().unwrap();
    }

    #[test]
    fn defaults_migrate_and_fifo() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.sched, SchedPolicy::Fifo);
        assert!(cfg.elastic.migrate, "migration is the elastic default");
        assert!(!cfg.autoscale.enabled);
        // legacy abort-on-kill stays reachable (without autoscale)
        let doc = TomlDoc::parse("[elastic]\nenabled = true\nmigrate = false").unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert!(!cfg.elastic.migrate);
        cfg.validate().unwrap();
    }

    #[test]
    fn autoscale_validation_rules() {
        let mut cfg = RunConfig::default();
        cfg.autoscale.enabled = true;
        assert!(cfg.validate().is_err(), "autoscale without elastic refused");

        cfg.elastic.enabled = true;
        cfg.validate().unwrap();

        cfg.elastic.migrate = false;
        assert!(
            cfg.validate().is_err(),
            "autoscale without migration refused (descale would discard work)"
        );
        cfg.elastic.migrate = true;

        cfg.autoscale.up_patience = 0;
        assert!(cfg.validate().is_err(), "zero patience refused");
        cfg.autoscale.up_patience = 1;

        cfg.autoscale.supply_high_frac = 1.5;
        assert!(cfg.validate().is_err(), "saturation fraction > 1 refused");
        cfg.autoscale.supply_high_frac = 0.8;

        cfg.autoscale.backlog_per_actor = 0.0;
        assert!(cfg.validate().is_err(), "non-positive backlog threshold refused");
    }

    #[test]
    fn rejects_unknown_sched_policy() {
        let doc = TomlDoc::parse("[sched]\npolicy = \"srpt\"").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn parses_kv_section() {
        let doc = TomlDoc::parse(
            r#"
            [kv]
            block_size = 8
            overcommit = 2.5
            preempt_policy = "youngest"
            replay_batch = 6
            layout = "paged"
            prefill_chunk = 8
            "#,
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.kv.block_size, 8);
        assert_eq!(cfg.kv.overcommit, 2.5);
        assert_eq!(cfg.kv.preempt, PreemptPolicy::Youngest);
        assert_eq!(cfg.kv.replay_batch, 6);
        assert_eq!(cfg.kv.layout, KvLayout::Paged);
        assert_eq!(cfg.kv.prefill_chunk, 8);
        cfg.validate().unwrap();
        // defaults: exact pool, no preemption, coalescing on, dense cache,
        // token-at-a-time prefill
        let d = RunConfig::default();
        assert_eq!(d.kv.block_size, 16);
        assert_eq!(d.kv.overcommit, 1.0);
        assert_eq!(d.kv.preempt, PreemptPolicy::None);
        assert_eq!(d.kv.replay_batch, 4);
        assert_eq!(d.kv.layout, KvLayout::Dense);
        assert_eq!(d.kv.prefill_chunk, 1);
    }

    #[test]
    fn kv_validation_rules() {
        let doc = TomlDoc::parse("[kv]\npreempt_policy = \"oldest\"").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err(), "unknown victim rule refused");

        let doc = TomlDoc::parse("[kv]\nlayout = \"ragged\"").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err(), "unknown cache layout refused");

        let mut cfg = RunConfig::default();
        cfg.kv.block_size = 0;
        assert!(cfg.validate().is_err(), "zero block size refused");

        let mut cfg = RunConfig::default();
        cfg.kv.overcommit = 0.0;
        assert!(cfg.validate().is_err(), "non-positive overcommit refused");
        cfg.kv.overcommit = f64::NAN;
        assert!(cfg.validate().is_err(), "NaN overcommit refused");

        let mut cfg = RunConfig::default();
        cfg.kv.replay_batch = 0;
        assert!(cfg.validate().is_err(), "zero replay batch refused");

        let mut cfg = RunConfig::default();
        cfg.kv.prefill_chunk = 0;
        assert!(cfg.validate().is_err(), "zero prefill chunk refused");

        // oversubscription without preemption stays legal (the ablation
        // baseline: legacy stall-in-place)
        let mut cfg = RunConfig::default();
        cfg.kv.overcommit = 2.0;
        cfg.validate().unwrap();
    }

    #[test]
    fn parses_and_validates_trainer_failover() {
        let doc = TomlDoc::parse(
            r#"
            [elastic]
            enabled = true
            trainer_failover = true
            trainer_restarts = 3
            [checkpoint]
            every = 2
            dir = "ckpts"
            "#,
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert!(cfg.elastic.trainer_failover);
        assert_eq!(cfg.elastic.trainer_restarts, 3);
        cfg.validate().unwrap();
        // defaults: off, one restart budgeted
        let d = RunConfig::default();
        assert!(!d.elastic.trainer_failover);
        assert_eq!(d.elastic.trainer_restarts, 1);
    }

    #[test]
    fn trainer_failover_requires_durable_checkpoints() {
        // failover without the elastic supervisor would be silently inert
        let mut cfg = RunConfig::default();
        cfg.elastic.trainer_failover = true;
        cfg.checkpoint.every = 2;
        cfg.checkpoint.dir = Some("ckpts".into());
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("elastic supervisor"), "{err}");

        let mut cfg = RunConfig::default();
        cfg.elastic.enabled = true;
        cfg.elastic.trainer_failover = true;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("durable recovery points"), "{err}");

        cfg.checkpoint.every = 2;
        cfg.checkpoint.dir = Some("ckpts".into());
        cfg.validate().unwrap();

        cfg.elastic.trainer_restarts = 0;
        assert!(cfg.validate().is_err(), "zero failover budget refused");
        cfg.elastic.trainer_restarts = 1;

        cfg.mode = Mode::Conventional { g: 4 };
        cfg.elastic.enabled = false;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("pipeline mode"), "{err}");
    }

    /// Satellite: every `[kv]`/`[autoscale]`/`[sched]`/`[checkpoint]`
    /// (and `[elastic]`) field survives parse → serialize → parse.
    #[test]
    fn property_config_sections_roundtrip() {
        crate::testkit::check("config section roundtrip", 120, 0xc0f6, 32, |c| {
            let mut cfg = RunConfig::default();
            cfg.sched = *c.rng.choice(&[SchedPolicy::Fifo, SchedPolicy::LongestPrefixFirst]);
            cfg.kv.block_size = c.usize_in(1, 64);
            cfg.kv.overcommit = (1 + c.rng.below(80)) as f64 / 16.0;
            cfg.kv.preempt = *c.rng.choice(&[PreemptPolicy::None, PreemptPolicy::Youngest]);
            cfg.kv.replay_batch = c.usize_in(1, 12);
            cfg.kv.layout = *c.rng.choice(&[KvLayout::Dense, KvLayout::Paged]);
            cfg.kv.prefill_chunk = c.usize_in(1, 16);
            cfg.checkpoint.every = c.usize_in(0, 9);
            cfg.checkpoint.keep_last = c.usize_in(0, 5);
            cfg.checkpoint.write_retries = c.usize_in(0, 4);
            if c.rng.below(2) == 1 {
                // occasionally exercise the escaping path (quotes are the
                // one special character the minimal TOML subset supports)
                let quirk = if c.rng.below(4) == 0 { "\"q\"" } else { "" };
                cfg.checkpoint.dir = Some(format!("ckpt_dir_{}{quirk}", c.rng.below(100)));
            }
            if c.rng.below(2) == 1 {
                cfg.checkpoint.resume_from = Some(format!("resume_{}", c.rng.below(100)));
            }
            cfg.elastic.enabled = c.rng.below(2) == 1;
            cfg.elastic.min_actors = c.usize_in(1, 3);
            cfg.elastic.max_actors = c.usize_in(3, 9);
            cfg.elastic.max_restarts = c.usize_in(0, 200);
            cfg.elastic.poll_ms = c.usize_in(1, 50) as u64;
            cfg.elastic.migrate = c.rng.below(2) == 1;
            cfg.elastic.trainer_failover = c.rng.below(2) == 1;
            cfg.elastic.trainer_restarts = c.usize_in(1, 5);
            cfg.autoscale.enabled = c.rng.below(2) == 1;
            cfg.autoscale.backlog_per_actor = (1 + c.rng.below(64)) as f64 / 8.0;
            cfg.autoscale.supply_high_frac = (1 + c.rng.below(16)) as f64 / 16.0;
            cfg.autoscale.up_patience = c.usize_in(1, 9) as u32;
            cfg.autoscale.down_patience = c.usize_in(1, 9) as u32;
            cfg.autoscale.cooldown = c.usize_in(0, 9) as u32;
            cfg.autoscale.max_lag_steps = c.rng.below(10) as f64;
            cfg.autoscale.ess_floor = c.rng.below(16) as f64 / 16.0;
            cfg.autoscale.min_batch_fill = c.rng.below(16) as f64 / 16.0;
            cfg.autoscale.eval_every_ms = c.usize_in(0, 100) as u64;
            cfg.clip_c = (1 + c.rng.below(64)) as f64 / 8.0;
            cfg.is_correction =
                *c.rng.choice(&[IsCorrection::None, IsCorrection::Truncated]);
            cfg.ess_floor = c.rng.below(16) as f64 / 16.0;
            cfg.train_truncated = c.rng.below(2) == 1;
            cfg.control.enabled = c.rng.below(2) == 1;
            cfg.control.window = c.usize_in(1, 16);
            cfg.control.reward_drop = c.rng.below(16) as f64 / 16.0;
            cfg.control.ess_trip_limit = c.rng.below(8) as f64;
            cfg.control.max_lag_steps = c.rng.below(10) as f64;
            cfg.control.rollback_budget = c.usize_in(1, 5);
            cfg.control.retry_backoff_ms = c.usize_in(0, 500) as u64;
            cfg.gateway.enabled = c.rng.below(2) == 1;
            cfg.gateway.interactive_queue = c.usize_in(1, 128);
            cfg.gateway.batch_queue = c.usize_in(1, 512);
            cfg.gateway.tenant_kv_frac = (1 + c.rng.below(16)) as f64 / 16.0;
            cfg.gateway.preempt = c.rng.below(2) == 1;
            cfg.gateway.slo_p99_ticks = (1 + c.rng.below(64)) as f64;

            let text = cfg.sections_to_toml();
            let doc = TomlDoc::parse(&text).map_err(|e| format!("emitted TOML: {e}"))?;
            let back = RunConfig::from_doc(&doc).map_err(|e| format!("reparse: {e}"))?;
            if back.sched != cfg.sched {
                return Err(format!("[sched] drift: {:?} vs {:?}", back.sched, cfg.sched));
            }
            if back.kv != cfg.kv {
                return Err(format!("[kv] drift: {:?} vs {:?}", back.kv, cfg.kv));
            }
            if back.checkpoint != cfg.checkpoint {
                return Err(format!(
                    "[checkpoint] drift: {:?} vs {:?}",
                    back.checkpoint, cfg.checkpoint
                ));
            }
            if back.elastic != cfg.elastic {
                return Err(format!(
                    "[elastic] drift: {:?} vs {:?}",
                    back.elastic, cfg.elastic
                ));
            }
            if back.autoscale != cfg.autoscale {
                return Err(format!(
                    "[autoscale] drift: {:?} vs {:?}",
                    back.autoscale, cfg.autoscale
                ));
            }
            if back.control != cfg.control {
                return Err(format!(
                    "[control] drift: {:?} vs {:?}",
                    back.control, cfg.control
                ));
            }
            if back.gateway != cfg.gateway {
                return Err(format!(
                    "[gateway] drift: {:?} vs {:?}",
                    back.gateway, cfg.gateway
                ));
            }
            if back.clip_c != cfg.clip_c
                || back.is_correction != cfg.is_correction
                || back.ess_floor != cfg.ess_floor
                || back.train_truncated != cfg.train_truncated
            {
                return Err(format!(
                    "[rl] drift: ({}, {}, {}, {}) vs ({}, {}, {}, {})",
                    back.clip_c,
                    back.is_correction.name(),
                    back.ess_floor,
                    back.train_truncated,
                    cfg.clip_c,
                    cfg.is_correction.name(),
                    cfg.ess_floor,
                    cfg.train_truncated
                ));
            }
            // a second serialize must be byte-stable (no format drift)
            if back.sections_to_toml() != text {
                return Err("serialize → parse → serialize is not a fixpoint".into());
            }
            Ok(())
        });
    }

    #[test]
    fn parses_control_section() {
        let doc = TomlDoc::parse(
            r#"
            [elastic]
            enabled = true
            trainer_failover = true
            [checkpoint]
            every = 2
            dir = "ckpts"
            write_retries = 3
            [control]
            enabled = true
            window = 12
            reward_drop = 0.25
            ess_trip_limit = 2
            max_lag_steps = 6
            rollback_budget = 4
            retry_backoff_ms = 125
            "#,
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert!(cfg.control.enabled);
        assert_eq!(cfg.control.window, 12);
        assert_eq!(cfg.control.reward_drop, 0.25);
        assert_eq!(cfg.control.ess_trip_limit, 2.0);
        assert_eq!(cfg.control.max_lag_steps, 6.0);
        assert_eq!(cfg.control.rollback_budget, 4);
        assert_eq!(cfg.control.retry_backoff_ms, 125);
        assert_eq!(cfg.checkpoint.write_retries, 3);
        cfg.validate().unwrap();
        // defaults: control plane off, two write retries budgeted
        let d = RunConfig::default();
        assert!(!d.control.enabled);
        assert_eq!(d.control.window, 8);
        assert_eq!(d.control.rollback_budget, 2);
        assert_eq!(d.checkpoint.write_retries, 2);
    }

    #[test]
    fn parses_gateway_section() {
        let doc = TomlDoc::parse(
            r#"
            [gateway]
            enabled = true
            interactive_queue = 8
            batch_queue = 32
            tenant_kv_frac = 0.25
            preempt = false
            slo_p99_ticks = 40
            "#,
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert!(cfg.gateway.enabled);
        assert_eq!(cfg.gateway.interactive_queue, 8);
        assert_eq!(cfg.gateway.batch_queue, 32);
        assert_eq!(cfg.gateway.tenant_kv_frac, 0.25);
        assert!(!cfg.gateway.preempt);
        assert_eq!(cfg.gateway.slo_p99_ticks, 40.0);
        cfg.validate().unwrap();
        // the front door stays closed by default — nothing constructs a
        // gateway, so existing runs are bit-for-bit identical
        let d = RunConfig::default();
        assert!(!d.gateway.enabled);
        assert_eq!(d.gateway.interactive_queue, 64);
        assert_eq!(d.gateway.batch_queue, 256);
        assert_eq!(d.gateway.tenant_kv_frac, 0.5);
        assert!(d.gateway.preempt);
    }

    #[test]
    fn gateway_section_rejects_degenerate_values() {
        let mut cfg = RunConfig::default();
        cfg.gateway.enabled = true;
        cfg.validate().unwrap();

        cfg.gateway.interactive_queue = 0;
        assert!(cfg.validate().is_err(), "zero interactive queue refused");
        cfg.gateway.interactive_queue = 1;
        cfg.gateway.batch_queue = 0;
        assert!(cfg.validate().is_err(), "zero batch queue refused");
        cfg.gateway.batch_queue = 1;

        cfg.gateway.tenant_kv_frac = 0.0;
        assert!(cfg.validate().is_err(), "zero tenant budget refused");
        cfg.gateway.tenant_kv_frac = 1.5;
        assert!(cfg.validate().is_err(), "over-unity tenant budget refused");
        cfg.gateway.tenant_kv_frac = f64::NAN;
        assert!(cfg.validate().is_err(), "NaN tenant budget refused");
        cfg.gateway.tenant_kv_frac = 1.0;

        cfg.gateway.slo_p99_ticks = 0.0;
        assert!(cfg.validate().is_err(), "zero SLO refused");
        cfg.gateway.slo_p99_ticks = f64::INFINITY;
        assert!(cfg.validate().is_err(), "infinite SLO refused");
        cfg.gateway.slo_p99_ticks = 25.0;
        cfg.validate().unwrap();

        // disabled gateway never constrains the rest of the config
        let mut cfg = RunConfig::default();
        cfg.gateway.interactive_queue = 0;
        cfg.gateway.tenant_kv_frac = -1.0;
        cfg.validate().unwrap();
    }

    #[test]
    fn control_plane_requires_trainer_failover() {
        // a guardrail that cannot roll back would be a silent no-op
        let mut cfg = RunConfig::default();
        cfg.control.enabled = true;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("trainer_failover"), "{err}");

        cfg.elastic.enabled = true;
        cfg.elastic.trainer_failover = true;
        cfg.checkpoint.every = 2;
        cfg.checkpoint.dir = Some("ckpts".into());
        cfg.validate().unwrap();

        cfg.control.window = 0;
        assert!(cfg.validate().is_err(), "zero window refused");
        cfg.control.window = 8;

        cfg.control.reward_drop = 1.5;
        assert!(cfg.validate().is_err(), "reward_drop above 1 refused");
        cfg.control.reward_drop = f64::NAN;
        assert!(cfg.validate().is_err(), "NaN reward_drop refused");
        cfg.control.reward_drop = 0.5;

        cfg.control.max_lag_steps = -1.0;
        assert!(cfg.validate().is_err(), "negative lag limit refused");
        cfg.control.max_lag_steps = 0.0;

        cfg.control.rollback_budget = 0;
        assert!(cfg.validate().is_err(), "zero rollback budget refused");
        cfg.control.rollback_budget = 1;
        cfg.validate().unwrap();

        // disabled control plane never constrains the rest of the config
        let mut cfg = RunConfig::default();
        cfg.control.window = 0;
        cfg.control.rollback_budget = 0;
        cfg.validate().unwrap();
    }

    /// Satellite: the documented refusal messages for invalid combos.
    #[test]
    fn invalid_combos_fail_with_documented_messages() {
        let mut cfg = RunConfig::default();
        cfg.autoscale.enabled = true;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(
            err.contains("autoscale requires the elastic actor pool"),
            "documented autoscale-without-elastic message, got: {err}"
        );

        let mut cfg = RunConfig::default();
        cfg.kv.replay_batch = 0;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(
            err.contains("kv.replay_batch must be >= 1"),
            "documented replay_batch message, got: {err}"
        );
    }

    #[test]
    fn rejects_unknown_mode() {
        let doc = TomlDoc::parse("[run]\nmode = \"warp\"").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn mode_names() {
        assert_eq!(Mode::Pipeline.name(), "pipeline");
        assert_eq!(Mode::Periodic { k: 4 }.name(), "periodic_k4");
        assert_eq!(Mode::Conventional { g: 8 }.name(), "conventional_g8");
    }

    #[test]
    fn parses_periodic_mode() {
        let doc = TomlDoc::parse("[run]\nmode = \"periodic\"\nk = 3").unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.mode, Mode::Periodic { k: 3 });
        cfg.validate().unwrap();
        // k defaults to 4 when omitted
        let doc = TomlDoc::parse("[run]\nmode = \"periodic\"").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().mode, Mode::Periodic { k: 4 });
        // k = 0 is refused at validation
        let mut cfg = RunConfig::default();
        cfg.mode = Mode::Periodic { k: 0 };
        assert!(cfg.validate().is_err(), "periodic k = 0 refused");
        // elastic stays pipeline-only: periodic is rejected like
        // conventional (the chaos/failover machinery assumes per-step
        // publishes)
        let mut cfg = RunConfig::default();
        cfg.elastic.enabled = true;
        cfg.mode = Mode::Periodic { k: 2 };
        assert!(cfg.validate().is_err(), "elastic + periodic refused");
    }

    #[test]
    fn parses_rl_correction_section() {
        let doc = TomlDoc::parse(
            r#"
            [rl]
            is_correction = "none"
            train_truncated = true
            "#,
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.is_correction, IsCorrection::None);
        assert!(cfg.train_truncated);
        cfg.validate().unwrap();
        // defaults: the paper's corrected objective, no floor, whole
        // rollouts only
        let d = RunConfig::default();
        assert_eq!(d.is_correction, IsCorrection::Truncated);
        assert_eq!(d.ess_floor, 0.0);
        assert!(!d.train_truncated);

        let doc = TomlDoc::parse("[rl]\nis_correction = \"clipped\"").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err(), "unknown correction refused");
    }

    #[test]
    fn ess_floor_validation_rules() {
        let mut cfg = RunConfig::default();
        cfg.ess_floor = 0.5;
        cfg.validate().unwrap();

        cfg.ess_floor = 1.5;
        assert!(cfg.validate().is_err(), "floor above 1 refused");
        cfg.ess_floor = f64::NAN;
        assert!(cfg.validate().is_err(), "NaN floor refused");

        cfg.ess_floor = 0.5;
        cfg.is_correction = IsCorrection::None;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("could never trip"), "{err}");

        // the autoscaler's own floor is range-checked too
        let mut cfg = RunConfig::default();
        cfg.elastic.enabled = true;
        cfg.autoscale.enabled = true;
        cfg.autoscale.ess_floor = 2.0;
        assert!(cfg.validate().is_err(), "autoscale floor above 1 refused");
        cfg.autoscale.ess_floor = 0.25;
        cfg.validate().unwrap();
    }
}
