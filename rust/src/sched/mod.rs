//! Sequence-level scheduling: portable in-flight rollouts, pluggable
//! admission, and signal-driven pool autoscaling.
//!
//! PipelineRL's core claim (paper §4) is that the accelerators stay
//! saturated because *sequences stay in flight across disruptions* — a
//! weight swap interrupts nothing, and ideally neither does generator
//! churn or a pool rescale. Before this module, that held only for
//! weight swaps: admission was FIFO hard-wired into the engine, a killed
//! actor aborted every in-flight sequence, and the pool resized only when
//! a chaos schedule said so. This module is the missing layer:
//!
//! * [`Scheduler`] ([`scheduler`]) — the admission *and eviction* policy,
//!   extracted out of `Engine::admit` behind a trait. [`scheduler::Fifo`]
//!   reproduces the legacy head-of-line behavior exactly;
//!   [`scheduler::LongestPrefixFirst`] prefers the queued sequence with
//!   the most already-generated tokens, so salvaged (migrated) prefixes
//!   re-enter decode first and their tokens accrue the least extra lag.
//!   Under KV block pressure the engine consults the trait's
//!   `pick_victim` hook ([`PreemptPolicy`], `[kv] preempt_policy`): the
//!   victim is parked through the snapshot path — blocks freed,
//!   re-admitted later via a coalesced replay — instead of stalling its
//!   slot, the vLLM preempt/swap analogue. This is the hook where
//!   OPPO-style (arXiv 2509.25762) stage-aware admission policies plug
//!   in without touching the engine.
//!
//! * [`SeqSnapshot`] ([`snapshot`]) — a *portable* in-flight sequence:
//!   prompt, generated prefix, per-token behavior logprobs and weight
//!   versions, cache position, budget, and the exporting engine's RNG
//!   cursor. Serializes to a compact byte format (`PRLSNAP1`,
//!   round-trips bit-exactly) so it can cross process boundaries. The
//!   engine exports snapshots on drain/kill and imports them on another
//!   actor, rebuilding the KV prefix with its existing replay path — the
//!   paper's "interrupted sequences resume after the update" property
//!   (§4), extended from weight swaps to actor churn (LlamaRL-style
//!   fully-async generator reconfiguration, arXiv 2505.24034).
//!
//! * [`MigrationHub`] ([`migrate`]) — the supervisor-side hand-off queue
//!   for exported snapshots. A killed or descaled actor deposits its
//!   in-flight sequences; surviving or replacement actors claim them
//!   (group ids preserved, so the preprocessor's advantage groups
//!   complete normally — no phantom aborts). Its depth is the
//!   *rollout-queue backlog*: in-flight rollouts waiting for generation
//!   capacity.
//!
//! * [`AutoScaler`] ([`autoscale`]) — hysteresis-guarded scale decisions
//!   from live pipeline signals, replacing chaos-only resize: sustained
//!   rollout-queue backlog (work waiting for an actor) grows the pool;
//!   a saturated rollout supply topic with zero backlog (generation
//!   outrunning training — dropped/stale tokens) shrinks it. Token lag
//!   and trainer batch fill act as guards. This is the OPPO dynamic
//!   stage-rebalancing analogue: capacity follows the live occupancy
//!   signals of the pipeline, not a static plan.
//!
//! Layering: this module depends only on `anyhow` — the engine
//! (`engine::sequence` ↔ [`SeqSnapshot`]), the coordinator
//! (supervisor ↔ [`AutoScaler`]/[`MigrationHub`]) and the cluster
//! simulator (`simcluster` ↔ [`AutoScaler`]) all sit above it.

pub mod autoscale;
pub mod migrate;
pub mod scheduler;
pub mod snapshot;

pub use autoscale::{AutoScaleCfg, AutoScaler, ScaleDecision, ScaleSignals};
pub use migrate::MigrationHub;
pub use scheduler::{KvLayout, PreemptPolicy, SchedPolicy, Scheduler, SeqView};
pub use snapshot::SeqSnapshot;
