//! Signal-driven actor-pool autoscaling with hysteresis.
//!
//! The supervisor used to resize the [`ActorPool`] only when a chaos
//! schedule told it to. The [`AutoScaler`] replaces that with the
//! OPPO-style (arXiv 2509.25762) feedback loop: capacity follows the
//! live occupancy signals of the pipeline.
//!
//! Signals (see [`ScaleSignals`]):
//!
//! * **rollout-queue backlog** — portable in-flight rollouts queued for
//!   (re)generation (the [`super::MigrationHub`] depth in the real
//!   system; the regeneration queue in the cluster simulator). Work is
//!   waiting for an actor: sustained backlog per live actor above
//!   `backlog_per_actor` scales **up**.
//! * **supply saturation** — the actor→preprocessor rollout topic depth
//!   relative to its capacity. A saturated supply buffer with *zero*
//!   backlog means generation is outrunning training (rollouts queue up,
//!   go stale, and a `DropOldest` ring starts discarding them): scales
//!   **down**.
//! * **token lag** (guard) — never scale up when mean token lag already
//!   exceeds `max_lag_steps`: extra actors raise rollout throughput and
//!   with it the lag of every in-flight token (paper §2.2), so adding
//!   capacity under high lag buys negative on-policyness.
//! * **batch ESS** (guard, alternative) — when `ess_floor > 0` the lag
//!   guard above is *replaced* by an effective-sample-size floor: scale
//!   up only while the trained batches' ESS (the host oracle,
//!   `train/ess_host`) stays at or above the floor. With truncated-IS
//!   correction on, lag per se is harmless — what matters is how much
//!   the correction costs in effective samples — so corrected runs may
//!   scale deeper into lag than a step-count cap would ever allow.
//! * **trainer batch fill** (guard) — never scale down while the trainer
//!   is packing starved batches (`batch_fill < min_batch_fill`).
//!
//! Hysteresis — the no-flapping contract — is enforced three ways:
//! a pressure must persist for `up_patience`/`down_patience` consecutive
//! evaluations before acting, any action starts a `cooldown` window of
//! forced holds, and the two patience counters reset each other (mixed
//! signals never accumulate). The decision function is pure in its
//! inputs, so schedules of signals replay deterministically — which is
//! how the tests (and the cluster simulator) pin its behavior.

/// `[autoscale]` configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoScaleCfg {
    /// drive `ActorPool` resize from live signals (pipeline + elastic
    /// runs only)
    pub enabled: bool,
    /// scale up when the rollout-queue backlog exceeds this many queued
    /// sequences per live actor
    pub backlog_per_actor: f64,
    /// scale down when the rollout supply topic sits at or above this
    /// fill fraction with zero backlog
    pub supply_high_frac: f64,
    /// consecutive over-pressure evaluations before scaling up
    pub up_patience: u32,
    /// consecutive over-pressure evaluations before scaling down
    pub down_patience: u32,
    /// evaluations held after any action (hysteresis window)
    pub cooldown: u32,
    /// token-lag ceiling for scale-up (optimizer steps); 0 disables
    pub max_lag_steps: f64,
    /// batch-ESS floor for scale-up in (0, 1]; when > 0 it *replaces*
    /// `max_lag_steps` as the freshness guard (IS-corrected runs cap the
    /// correction's cost in effective samples instead of raw lag). 0
    /// keeps the lag guard.
    pub ess_floor: f64,
    /// batch-fill floor for scale-down; 0 disables
    pub min_batch_fill: f64,
    /// evaluation cadence in the supervisor loop, milliseconds
    pub eval_every_ms: u64,
}

impl Default for AutoScaleCfg {
    fn default() -> Self {
        AutoScaleCfg {
            enabled: false,
            backlog_per_actor: 2.0,
            supply_high_frac: 0.75,
            up_patience: 3,
            down_patience: 5,
            cooldown: 8,
            max_lag_steps: 0.0,
            ess_floor: 0.0,
            min_batch_fill: 0.0,
            eval_every_ms: 25,
        }
    }
}

/// One evaluation's worth of live pipeline signals.
#[derive(Debug, Clone, Copy, Default)]
pub struct ScaleSignals {
    /// rollout-queue backlog: in-flight rollouts awaiting generation
    /// capacity (migration-hub depth / simulator regen queue)
    pub backlog: usize,
    /// rollout supply topic depth (actor → preprocessor)
    pub supply_depth: usize,
    /// rollout supply topic capacity
    pub supply_capacity: usize,
    /// mean token lag of the latest trained batch, optimizer steps
    pub token_lag: f64,
    /// latest trained batch's effective sample size in (0, 1] — the
    /// `train/ess` (device) or `train/ess_host` (oracle) series.
    /// Suppliers must set 1.0 when unknown; the derived `Default` is 0.0,
    /// which reads as "all samples wasted" and pins the ESS guard shut.
    pub ess: f64,
    /// latest trainer batch fill fraction (1.0 when unknown)
    pub batch_fill: f64,
    /// live actors
    pub pool: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Up,
    Down,
    Hold,
}

/// The stateful decision loop. Call [`AutoScaler::decide`] at a fixed
/// cadence; it returns at most one action per cooldown window.
#[derive(Debug)]
pub struct AutoScaler {
    cfg: AutoScaleCfg,
    up_streak: u32,
    down_streak: u32,
    cooldown_left: u32,
    ups: u64,
    downs: u64,
}

impl AutoScaler {
    pub fn new(cfg: AutoScaleCfg) -> AutoScaler {
        AutoScaler {
            cfg,
            up_streak: 0,
            down_streak: 0,
            cooldown_left: 0,
            ups: 0,
            downs: 0,
        }
    }

    pub fn cfg(&self) -> &AutoScaleCfg {
        &self.cfg
    }

    /// Total scale-up decisions issued so far.
    pub fn ups(&self) -> u64 {
        self.ups
    }

    /// Total scale-down decisions issued so far.
    pub fn downs(&self) -> u64 {
        self.downs
    }

    /// Evaluate one signal sample. Pure in the signal sequence: the same
    /// schedule of [`ScaleSignals`] produces the same decisions.
    pub fn decide(&mut self, s: &ScaleSignals) -> ScaleDecision {
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            return ScaleDecision::Hold;
        }
        let pool = s.pool.max(1) as f64;
        // Fail-safe: a zero supply capacity means the downstream topic is
        // unknown or closed, not infinitely absorbent. Mapping it to
        // `supply_frac = 0.0` (the old behavior) read as "nothing queued
        // downstream", so backlog could scale the pool up with nowhere to
        // drain and scale-down could never fire. Neither pressure is
        // evaluable without a real capacity, so hold — and reset both
        // streaks, because a Hold on unknown signals must not extend a
        // patience run built from known ones.
        if s.supply_capacity == 0 {
            self.up_streak = 0;
            self.down_streak = 0;
            return ScaleDecision::Hold;
        }
        let supply_frac = s.supply_depth as f64 / s.supply_capacity as f64;
        // A backlog only justifies more actors while the downstream can
        // absorb more throughput: with the supply buffer already
        // saturated, queued work will drain into freed slots anyway, and
        // scaling up on it would re-trigger growth right after every
        // scale-down hand-off (the descaled actor's own deposits) — an
        // up/down thrash loop.
        let up_pressure = s.backlog as f64 > self.cfg.backlog_per_actor * pool
            && supply_frac < self.cfg.supply_high_frac;
        // freshness guard: ESS floor (IS-corrected runs) replaces the raw
        // lag cap when configured — the two measure the same risk, and
        // applying both would re-impose the step cap the correction is
        // meant to relax. Non-finite signals fail safe *shut* (the
        // Guardrail contract): a NaN ess or token_lag means the telemetry
        // is broken, and `NaN >= floor` / `NaN < cap` are both false only
        // on the guarded branch that happens to be active — so every
        // branch, including "both guards disabled", must check finiteness
        // explicitly or a NaN would default the gate open.
        let lag_ok = if self.cfg.ess_floor > 0.0 {
            s.ess.is_finite() && s.ess >= self.cfg.ess_floor
        } else if self.cfg.max_lag_steps > 0.0 {
            s.token_lag.is_finite() && s.token_lag < self.cfg.max_lag_steps
        } else {
            s.token_lag.is_finite() && s.ess.is_finite()
        };
        let down_pressure = s.backlog == 0 && supply_frac >= self.cfg.supply_high_frac;
        let fill_ok = s.batch_fill >= self.cfg.min_batch_fill;

        if up_pressure && lag_ok {
            self.down_streak = 0;
            self.up_streak += 1;
            if self.up_streak >= self.cfg.up_patience.max(1) {
                self.up_streak = 0;
                self.cooldown_left = self.cfg.cooldown;
                self.ups += 1;
                return ScaleDecision::Up;
            }
        } else if down_pressure && fill_ok {
            self.up_streak = 0;
            self.down_streak += 1;
            if self.down_streak >= self.cfg.down_patience.max(1) {
                self.down_streak = 0;
                self.cooldown_left = self.cfg.cooldown;
                self.downs += 1;
                return ScaleDecision::Down;
            }
        } else {
            self.up_streak = 0;
            self.down_streak = 0;
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoScaleCfg {
        AutoScaleCfg {
            enabled: true,
            backlog_per_actor: 2.0,
            supply_high_frac: 0.75,
            up_patience: 3,
            down_patience: 3,
            cooldown: 4,
            max_lag_steps: 0.0,
            ess_floor: 0.0,
            min_batch_fill: 0.0,
            eval_every_ms: 0,
        }
    }

    fn backlog(n: usize, pool: usize) -> ScaleSignals {
        ScaleSignals {
            backlog: n,
            supply_depth: 0,
            supply_capacity: 16,
            token_lag: 0.0,
            ess: 1.0,
            batch_fill: 1.0,
            pool,
        }
    }

    fn saturated(pool: usize) -> ScaleSignals {
        ScaleSignals {
            backlog: 0,
            supply_depth: 16,
            supply_capacity: 16,
            token_lag: 0.0,
            ess: 1.0,
            batch_fill: 1.0,
            pool,
        }
    }

    #[test]
    fn sustained_backlog_scales_up_after_patience() {
        let mut a = AutoScaler::new(cfg());
        assert_eq!(a.decide(&backlog(10, 1)), ScaleDecision::Hold);
        assert_eq!(a.decide(&backlog(10, 1)), ScaleDecision::Hold);
        assert_eq!(a.decide(&backlog(10, 1)), ScaleDecision::Up);
        // cooldown: pressure continues but the scaler holds
        for _ in 0..4 {
            assert_eq!(a.decide(&backlog(10, 2)), ScaleDecision::Hold);
        }
        assert_eq!(a.ups(), 1);
    }

    #[test]
    fn backlog_threshold_scales_with_pool_size() {
        let mut a = AutoScaler::new(cfg());
        // 5 queued over 4 actors is under 2-per-actor: no pressure
        for _ in 0..10 {
            assert_eq!(a.decide(&backlog(5, 4)), ScaleDecision::Hold);
        }
        assert_eq!(a.ups(), 0);
    }

    #[test]
    fn saturation_with_zero_backlog_scales_down() {
        let mut a = AutoScaler::new(cfg());
        assert_eq!(a.decide(&saturated(3)), ScaleDecision::Hold);
        assert_eq!(a.decide(&saturated(3)), ScaleDecision::Hold);
        assert_eq!(a.decide(&saturated(3)), ScaleDecision::Down);
        assert_eq!(a.downs(), 1);
        // any backlog cancels the down pressure entirely
        let mut b = AutoScaler::new(cfg());
        let mut s = saturated(3);
        s.backlog = 1;
        for _ in 0..10 {
            assert_eq!(b.decide(&s), ScaleDecision::Hold);
        }
    }

    #[test]
    fn oscillating_signal_never_flaps() {
        // alternating pressure directions: neither patience accumulates,
        // so a noisy boundary signal produces zero actions
        let mut a = AutoScaler::new(cfg());
        for i in 0..50 {
            let s = if i % 2 == 0 { backlog(10, 1) } else { saturated(1) };
            assert_eq!(a.decide(&s), ScaleDecision::Hold, "eval {i}");
        }
        assert_eq!(a.ups() + a.downs(), 0);
    }

    #[test]
    fn saturated_supply_blocks_scale_up() {
        // a backlog behind an already-saturated downstream is drained by
        // freed slots, not by new actors — scaling up on it would thrash
        // (every scale-down's own hand-off would re-trigger growth)
        let mut a = AutoScaler::new(cfg());
        let mut s = backlog(10, 1);
        s.supply_depth = 16;
        for _ in 0..10 {
            assert_eq!(a.decide(&s), ScaleDecision::Hold);
        }
        assert_eq!(a.ups(), 0);
    }

    #[test]
    fn lag_guard_blocks_scale_up() {
        let mut c = cfg();
        c.max_lag_steps = 4.0;
        let mut a = AutoScaler::new(c);
        let mut s = backlog(10, 1);
        s.token_lag = 6.0;
        for _ in 0..10 {
            assert_eq!(a.decide(&s), ScaleDecision::Hold);
        }
        s.token_lag = 1.0;
        for _ in 0..2 {
            assert_eq!(a.decide(&s), ScaleDecision::Hold);
        }
        assert_eq!(a.decide(&s), ScaleDecision::Up);
    }

    #[test]
    fn ess_floor_blocks_scale_up_below_floor() {
        let mut c = cfg();
        c.ess_floor = 0.5;
        let mut a = AutoScaler::new(c);
        let mut s = backlog(10, 1);
        s.ess = 0.3; // correction is burning half the batch: hold
        for _ in 0..10 {
            assert_eq!(a.decide(&s), ScaleDecision::Hold);
        }
        assert_eq!(a.ups(), 0);
        s.ess = 0.8;
        for _ in 0..2 {
            assert_eq!(a.decide(&s), ScaleDecision::Hold);
        }
        assert_eq!(a.decide(&s), ScaleDecision::Up);
    }

    #[test]
    fn ess_floor_replaces_the_lag_guard() {
        // an IS-corrected run deep into lag but with healthy ESS may
        // still scale up — the whole point of the corrected dial
        let mut c = cfg();
        c.max_lag_steps = 4.0;
        c.ess_floor = 0.5;
        let mut a = AutoScaler::new(c);
        let mut s = backlog(10, 1);
        s.token_lag = 50.0; // way past the (inactive) lag cap
        s.ess = 0.9;
        assert_eq!(a.decide(&s), ScaleDecision::Hold);
        assert_eq!(a.decide(&s), ScaleDecision::Hold);
        assert_eq!(a.decide(&s), ScaleDecision::Up);
    }

    #[test]
    fn default_zero_ess_reads_as_guard_shut() {
        // ScaleSignals::default() leaves ess = 0.0 — a supplier that
        // forgets the signal must fail safe (never scale up), not
        // trivially pass
        let mut c = cfg();
        c.ess_floor = 0.5;
        let mut a = AutoScaler::new(c);
        let s = ScaleSignals {
            backlog: 10,
            supply_capacity: 16,
            batch_fill: 1.0,
            pool: 1,
            ..ScaleSignals::default()
        };
        for _ in 0..10 {
            assert_eq!(a.decide(&s), ScaleDecision::Hold);
        }
        assert_eq!(a.ups(), 0);
    }

    #[test]
    fn zero_supply_capacity_is_fail_safe_hold() {
        // regression: capacity 0 (downstream unknown/closed) used to read
        // as supply_frac = 0.0 — "infinitely absorbent" — so a backlog
        // scaled the pool up with nowhere to drain. It must hold instead.
        let mut a = AutoScaler::new(cfg());
        let mut s = backlog(10, 1);
        s.supply_capacity = 0;
        for _ in 0..10 {
            assert_eq!(a.decide(&s), ScaleDecision::Hold);
        }
        assert_eq!(a.ups(), 0);
        // and a saturated-shaped signal with capacity 0 must not scale
        // down either: neither pressure is evaluable
        let mut b = AutoScaler::new(cfg());
        let mut s = saturated(3);
        s.supply_capacity = 0;
        for _ in 0..10 {
            assert_eq!(b.decide(&s), ScaleDecision::Hold);
        }
        assert_eq!(b.downs(), 0);
    }

    #[test]
    fn zero_supply_capacity_resets_patience_streaks() {
        // a capacity dropout mid-patience-run must restart the count: two
        // good samples + a blind one + two good samples is not three
        // consecutive observations of pressure
        let mut a = AutoScaler::new(cfg());
        assert_eq!(a.decide(&backlog(10, 1)), ScaleDecision::Hold);
        assert_eq!(a.decide(&backlog(10, 1)), ScaleDecision::Hold);
        let mut blind = backlog(10, 1);
        blind.supply_capacity = 0;
        assert_eq!(a.decide(&blind), ScaleDecision::Hold);
        assert_eq!(a.decide(&backlog(10, 1)), ScaleDecision::Hold);
        assert_eq!(a.decide(&backlog(10, 1)), ScaleDecision::Hold);
        // only the third consecutive *evaluable* sample fires
        assert_eq!(a.decide(&backlog(10, 1)), ScaleDecision::Up);
    }

    #[test]
    fn nan_ess_blocks_scale_up_under_ess_floor() {
        let mut c = cfg();
        c.ess_floor = 0.5;
        let mut a = AutoScaler::new(c);
        let mut s = backlog(10, 1);
        s.ess = f64::NAN;
        for _ in 0..10 {
            assert_eq!(a.decide(&s), ScaleDecision::Hold);
        }
        assert_eq!(a.ups(), 0);
    }

    #[test]
    fn nan_token_lag_blocks_scale_up_under_lag_cap() {
        let mut c = cfg();
        c.max_lag_steps = 4.0;
        let mut a = AutoScaler::new(c);
        let mut s = backlog(10, 1);
        s.token_lag = f64::NAN;
        for _ in 0..10 {
            assert_eq!(a.decide(&s), ScaleDecision::Hold);
        }
        assert_eq!(a.ups(), 0);
    }

    #[test]
    fn nan_signals_block_scale_up_even_with_guards_disabled() {
        // regression: with max_lag_steps == 0 the old disjunct
        // short-circuited true, so a NaN token_lag (broken telemetry)
        // defaulted the freshness gate *open*. Fail-safe shut instead.
        let mut a = AutoScaler::new(cfg()); // both guards disabled
        let mut s = backlog(10, 1);
        s.token_lag = f64::NAN;
        for _ in 0..10 {
            assert_eq!(a.decide(&s), ScaleDecision::Hold);
        }
        let mut b = AutoScaler::new(cfg());
        let mut s = backlog(10, 1);
        s.ess = f64::INFINITY;
        for _ in 0..10 {
            assert_eq!(b.decide(&s), ScaleDecision::Hold);
        }
        assert_eq!(a.ups() + b.ups(), 0);
    }

    #[test]
    fn nan_batch_fill_blocks_scale_down() {
        // pin the already-safe path: `NaN >= min_batch_fill` is false, so
        // a NaN fill can never approve a scale-down
        let mut c = cfg();
        c.min_batch_fill = 0.5;
        let mut a = AutoScaler::new(c);
        let mut s = saturated(3);
        s.batch_fill = f64::NAN;
        for _ in 0..10 {
            assert_eq!(a.decide(&s), ScaleDecision::Hold);
        }
        assert_eq!(a.downs(), 0);
    }

    #[test]
    fn fill_guard_blocks_scale_down() {
        let mut c = cfg();
        c.min_batch_fill = 0.5;
        let mut a = AutoScaler::new(c);
        let mut s = saturated(3);
        s.batch_fill = 0.2; // trainer starving: keep the actors
        for _ in 0..10 {
            assert_eq!(a.decide(&s), ScaleDecision::Hold);
        }
        assert_eq!(a.downs(), 0);
    }

    /// The acceptance scenario on a deterministic mini-cluster: a backlog
    /// burst grows the pool until capacity absorbs it, the pool shrinks
    /// back once generation overruns training, and the whole trajectory
    /// is replayable with a bounded number of actions (no flapping).
    #[test]
    fn deterministic_sim_grows_under_backlog_and_shrinks_back() {
        let run = || {
            let mut a = AutoScaler::new(cfg());
            let (min_pool, max_pool) = (1usize, 4usize);
            let mut pool = min_pool;
            let mut backlog: usize = 60; // burst of orphaned rollouts
            let mut supply: usize = 0;
            let cap = 16usize;
            let mut trace = Vec::new();
            for tick in 0..200 {
                // each actor regenerates 2 queued seqs per tick and feeds
                // the supply buffer; the trainer drains 3 per tick
                let drained = (2 * pool).min(backlog);
                backlog -= drained;
                supply = (supply + 2 * pool).saturating_sub(3).min(cap);
                let s = ScaleSignals {
                    backlog,
                    supply_depth: supply,
                    supply_capacity: cap,
                    token_lag: 0.0,
                    ess: 1.0,
                    batch_fill: 1.0,
                    pool,
                };
                match a.decide(&s) {
                    ScaleDecision::Up => {
                        if pool < max_pool {
                            pool += 1;
                        }
                        trace.push((tick, "up", pool));
                    }
                    ScaleDecision::Down => {
                        if pool > min_pool {
                            pool -= 1;
                        }
                        trace.push((tick, "down", pool));
                    }
                    ScaleDecision::Hold => {}
                }
            }
            (pool, a.ups(), a.downs(), trace)
        };
        let (pool, ups, downs, trace) = run();
        assert!(ups >= 1, "sustained backlog must grow the pool: {trace:?}");
        assert!(downs >= 1, "cleared backlog + saturated supply must shrink it: {trace:?}");
        assert_eq!(pool, 1, "pool returns to the floor: {trace:?}");
        // no flapping: every action is load-bearing, bounded by the
        // peak-to-floor distance in each direction
        assert!(ups <= 3 && downs <= 3, "flapping: {trace:?}");
        // deterministic: the exact trajectory replays
        let again = run();
        assert_eq!(trace, again.3);
    }
}
