//! Portable in-flight sequences.
//!
//! A [`SeqSnapshot`] is everything another engine needs to *resume* a
//! sequence mid-generation: the forced prompt, the generated prefix with
//! its per-token behavior logprobs and weight-version tags (the rollout
//! record's raw material — nothing sampled so far is lost), the cache
//! position, the remaining generation budget, and the exporting engine's
//! RNG cursor (PCG state words, see `util::Rng::state_words`). The
//! importer rebuilds the KV prefix by replaying the stream under its own
//! weights (the engine's existing recompute path), then continues
//! sampling where the exporter stopped.
//!
//! The byte format (`PRLSNAP1`, all little-endian, fixed field order) is
//! the process-boundary form: serialize → deserialize → serialize is
//! byte-identical (property-tested in tests/migration.rs), so snapshots
//! can be content-addressed, logged, or shipped over any transport
//! without drift.
//!
//! ```text
//! magic "PRLSNAP1"                      8 bytes
//! seq_id, group_id, problem_id          u64 ×3
//! pos, max_new                          u64 ×2
//! rng_words                             u64 ×4
//! t_start                               f64
//! prompt_len, gen_len                   u32 ×2
//! prompt                                i32 × prompt_len
//! gen_tokens                            i32 × gen_len
//! behavior_lp                           f32 × gen_len
//! token_version                         u64 × gen_len
//! ```

use anyhow::{bail, Result};

const MAGIC: &[u8; 8] = b"PRLSNAP1";

/// A serializable, resumable in-flight sequence. See module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct SeqSnapshot {
    /// engine-local id on the *exporting* engine (informational: the
    /// importer assigns its own)
    pub seq_id: u64,
    /// advantage-group id — preserved verbatim so the preprocessor's
    /// group completes normally wherever the sequence finishes
    pub group_id: u64,
    /// stable problem id (problems regenerate deterministically from it)
    pub problem_id: u64,
    /// `[BOS, prompt...]` — the forced prefix
    pub prompt: Vec<i32>,
    /// generated prefix (the salvaged tokens)
    pub gen_tokens: Vec<i32>,
    /// behavior-policy logprob per generated token
    pub behavior_lp: Vec<f32>,
    /// weight version each generated token was sampled under
    pub token_version: Vec<u64>,
    /// next cache position to write (== tokens fed so far)
    pub pos: usize,
    /// total generation budget (the prefix counts against it)
    pub max_new: usize,
    /// exporting engine's RNG cursor at export time (PCG state words) —
    /// lets a deterministic harness continue the exact sampling stream
    pub rng_words: [u64; 4],
    /// generation start on the exporter's clock (informational; importers
    /// restart the clock)
    pub t_start: f64,
}

impl SeqSnapshot {
    pub fn total_len(&self) -> usize {
        self.prompt.len() + self.gen_tokens.len()
    }

    /// Generated tokens this snapshot preserves (the "salvaged" count).
    pub fn salvaged_tokens(&self) -> usize {
        self.gen_tokens.len()
    }

    /// Structural consistency: parallel arrays parallel, position inside
    /// the stream and consistent with the prefill/decode phase split.
    pub fn validate(&self) -> Result<()> {
        if self.prompt.is_empty() {
            bail!("snapshot has an empty prompt (missing BOS)");
        }
        if self.gen_tokens.len() != self.behavior_lp.len()
            || self.gen_tokens.len() != self.token_version.len()
        {
            bail!(
                "snapshot arrays disagree: {} tokens, {} lps, {} versions",
                self.gen_tokens.len(),
                self.behavior_lp.len(),
                self.token_version.len()
            );
        }
        if self.pos >= self.total_len() {
            bail!(
                "snapshot pos {} outside stream of length {}",
                self.pos,
                self.total_len()
            );
        }
        // once decoding has produced tokens, pos must sit at the stream end
        if !self.gen_tokens.is_empty() && self.pos != self.total_len() - 1 {
            bail!(
                "snapshot pos {} inconsistent with {} generated tokens (want {})",
                self.pos,
                self.gen_tokens.len(),
                self.total_len() - 1
            );
        }
        if self.gen_tokens.len() > self.max_new {
            bail!(
                "snapshot prefix {} exceeds generation budget {}",
                self.gen_tokens.len(),
                self.max_new
            );
        }
        Ok(())
    }

    /// Serialize to the `PRLSNAP1` byte format (see module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let g = self.gen_tokens.len();
        let mut out = Vec::with_capacity(8 + 9 * 8 + 8 + 8 + self.prompt.len() * 4 + g * 16);
        out.extend_from_slice(MAGIC);
        for x in [
            self.seq_id,
            self.group_id,
            self.problem_id,
            self.pos as u64,
            self.max_new as u64,
            self.rng_words[0],
            self.rng_words[1],
            self.rng_words[2],
            self.rng_words[3],
        ] {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out.extend_from_slice(&self.t_start.to_le_bytes());
        out.extend_from_slice(&(self.prompt.len() as u32).to_le_bytes());
        out.extend_from_slice(&(g as u32).to_le_bytes());
        for t in &self.prompt {
            out.extend_from_slice(&t.to_le_bytes());
        }
        for t in &self.gen_tokens {
            out.extend_from_slice(&t.to_le_bytes());
        }
        for l in &self.behavior_lp {
            out.extend_from_slice(&l.to_le_bytes());
        }
        for v in &self.token_version {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Deserialize from [`SeqSnapshot::to_bytes`] output. Rejects bad
    /// magic, truncation, and trailing garbage; the result is validated.
    pub fn from_bytes(bytes: &[u8]) -> Result<SeqSnapshot> {
        let mut r = Reader { buf: bytes, at: 0 };
        let magic = r.take(8)?;
        if magic != MAGIC {
            bail!("not a PRLSNAP1 sequence snapshot");
        }
        let seq_id = r.u64()?;
        let group_id = r.u64()?;
        let problem_id = r.u64()?;
        let pos = r.u64()? as usize;
        let max_new = r.u64()? as usize;
        let rng_words = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
        let t_start = f64::from_le_bytes(r.take(8)?.try_into().expect("8 bytes"));
        let prompt_len = r.u32()? as usize;
        let gen_len = r.u32()? as usize;
        let prompt = r.i32s(prompt_len)?;
        let gen_tokens = r.i32s(gen_len)?;
        let mut behavior_lp = Vec::with_capacity(gen_len);
        for _ in 0..gen_len {
            behavior_lp.push(f32::from_le_bytes(r.take(4)?.try_into().expect("4 bytes")));
        }
        let mut token_version = Vec::with_capacity(gen_len);
        for _ in 0..gen_len {
            token_version.push(r.u64()?);
        }
        if r.at != bytes.len() {
            bail!("snapshot has {} trailing bytes", bytes.len() - r.at);
        }
        let snap = SeqSnapshot {
            seq_id,
            group_id,
            problem_id,
            prompt,
            gen_tokens,
            behavior_lp,
            token_version,
            pos,
            max_new,
            rng_words,
            t_start,
        };
        snap.validate()?;
        Ok(snap)
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.at + n > self.buf.len() {
            bail!(
                "snapshot truncated: need {} bytes at offset {}, have {}",
                n,
                self.at,
                self.buf.len() - self.at
            );
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn i32s(&mut self, n: usize) -> Result<Vec<i32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SeqSnapshot {
        SeqSnapshot {
            seq_id: 42,
            group_id: (3u64 << 40) | 7,
            problem_id: 99,
            prompt: vec![1, 10, 11, 12],
            gen_tokens: vec![20, 21, 22],
            behavior_lp: vec![-0.5, -1.25, -0.0625],
            token_version: vec![4, 4, 5],
            pos: 6,
            max_new: 16,
            rng_words: [0xdead, 0xbeef, 0xf00d, 0xcafe],
            t_start: 12.75,
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let s = sample();
        s.validate().unwrap();
        let b = s.to_bytes();
        let s2 = SeqSnapshot::from_bytes(&b).unwrap();
        assert_eq!(s, s2);
        assert_eq!(s2.to_bytes(), b, "re-serialization is byte-identical");
    }

    #[test]
    fn prefill_snapshot_roundtrips() {
        let mut s = sample();
        s.gen_tokens.clear();
        s.behavior_lp.clear();
        s.token_version.clear();
        s.pos = 1; // mid-prefill
        s.validate().unwrap();
        let s2 = SeqSnapshot::from_bytes(&s.to_bytes()).unwrap();
        assert_eq!(s, s2);
        assert_eq!(s2.salvaged_tokens(), 0);
    }

    #[test]
    fn rejects_bad_magic_truncation_and_trailing_bytes() {
        let b = sample().to_bytes();
        let mut bad = b.clone();
        bad[0] = b'X';
        assert!(SeqSnapshot::from_bytes(&bad).is_err(), "bad magic");
        assert!(SeqSnapshot::from_bytes(&b[..b.len() - 1]).is_err(), "truncated");
        let mut long = b.clone();
        long.push(0);
        assert!(SeqSnapshot::from_bytes(&long).is_err(), "trailing bytes");
    }

    #[test]
    fn validate_catches_inconsistencies() {
        let mut s = sample();
        s.behavior_lp.pop();
        assert!(s.validate().is_err(), "skewed arrays");

        let mut s = sample();
        s.pos = 99;
        assert!(s.validate().is_err(), "pos outside stream");

        let mut s = sample();
        s.pos = 3; // decode prefix present but pos not at stream end
        assert!(s.validate().is_err(), "pos inconsistent with prefix");

        let mut s = sample();
        s.max_new = 2;
        assert!(s.validate().is_err(), "prefix over budget");

        let mut s = sample();
        s.prompt.clear();
        assert!(s.validate().is_err(), "empty prompt");
    }
}
