//! Cross-actor hand-off of portable in-flight rollouts.
//!
//! When an actor is killed (chaos, crash-restart) or descaled (autoscale
//! down, `RemoveActor`), its engine exports every in-flight sequence as a
//! [`SeqSnapshot`] and *deposits* it here; surviving or replacement
//! actors *claim* snapshots as slot capacity frees and resume them
//! (group ids preserved, prefixes intact). The hub is therefore the
//! system's **rollout queue**: its depth is the backlog of in-flight
//! rollouts waiting for generation capacity — the autoscaler's primary
//! scale-up signal.
//!
//! Accounting invariant (asserted by the chaos-harness tests): every
//! deposited snapshot is eventually either *claimed* (its sequence
//! completes on another actor) or *discarded* (deliberately dropped at
//! run shutdown, rejected by an importer, or refused at decode) —
//! `deposited == claimed + discarded + depth` at all times, so no
//! salvageable token can be silently lost.
//!
//! **Byzantine deposits.** Snapshots that crossed a process boundary
//! arrive as `PRLSNAP1` bytes ([`MigrationHub::deposit_raw`]) and are
//! decoded at *claim* time: a corrupt blob (bit flips, truncation —
//! `ChaosKind::CorruptSnapshot` injects exactly this) is rejected by
//! `SeqSnapshot::from_bytes`, counted as discarded (+
//! `corrupt_rejected`), and never reaches an actor — the books stay
//! balanced and the claimer survives.

use super::snapshot::SeqSnapshot;
use std::collections::VecDeque;
use std::sync::Mutex;

/// A queued deposit: typed (in-process hand-off) or wire-form bytes
/// (cross-process / byzantine path, decoded at claim time).
#[derive(Debug)]
enum Entry {
    Snap(SeqSnapshot),
    Raw(Vec<u8>),
}

#[derive(Debug, Default)]
struct HubState {
    queue: VecDeque<Entry>,
    deposited: u64,
    claimed: u64,
    discarded: u64,
    /// wire-form deposits rejected at decode (byzantine)
    corrupt_rejected: u64,
    tokens_deposited: u64,
    tokens_claimed: u64,
}

/// Thread-safe snapshot hand-off queue (see module docs). Shared as an
/// `Arc<MigrationHub>` between the supervisor and every actor.
#[derive(Debug, Default)]
pub struct MigrationHub {
    inner: Mutex<HubState>,
}

impl MigrationHub {
    pub fn new() -> MigrationHub {
        MigrationHub::default()
    }

    /// Queue snapshots for re-generation (kill/descale path). Returns the
    /// number deposited.
    pub fn deposit(&self, snaps: Vec<SeqSnapshot>) -> usize {
        let mut g = self.inner.lock().unwrap();
        let n = snaps.len();
        g.deposited += n as u64;
        for s in snaps {
            g.tokens_deposited += s.salvaged_tokens() as u64;
            g.queue.push_back(Entry::Snap(s));
        }
        n
    }

    /// Queue one wire-form (`PRLSNAP1` bytes) deposit — the
    /// process-boundary path. The blob is decoded at claim time; a
    /// corrupt one is rejected there and accounted as discarded, so a
    /// byzantine peer can waste a queue slot but never poison a claimer
    /// or unbalance the books.
    pub fn deposit_raw(&self, bytes: Vec<u8>) {
        let mut g = self.inner.lock().unwrap();
        g.deposited += 1;
        g.queue.push_back(Entry::Raw(bytes));
    }

    /// Claim up to `max` snapshots for resumption (FIFO — oldest orphans
    /// first; the engine-side scheduler decides their admission order).
    /// Wire-form deposits are decoded here; rejects are discarded with
    /// the books updated and do not count against `max`.
    pub fn claim(&self, max: usize) -> Vec<SeqSnapshot> {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        while out.len() < max {
            let Some(entry) = g.queue.pop_front() else { break };
            let snap = match entry {
                Entry::Snap(s) => s,
                Entry::Raw(bytes) => match SeqSnapshot::from_bytes(&bytes) {
                    Ok(s) => {
                        // deposit-time token accounting was deferred (the
                        // blob was opaque); land both sides together so
                        // the salvage ledger stays conservative
                        g.tokens_deposited += s.salvaged_tokens() as u64;
                        s
                    }
                    Err(_) => {
                        g.discarded += 1;
                        g.corrupt_rejected += 1;
                        continue;
                    }
                },
            };
            g.tokens_claimed += snap.salvaged_tokens() as u64;
            g.claimed += 1;
            out.push(snap);
        }
        out
    }

    /// Return a claimed-but-unusable snapshot to the ledger as discarded
    /// (the importer rejected it: config skew, malformed deposit). Moves
    /// the sequence and its tokens from the claimed to the discarded
    /// column, so the conservation books — and the tokens-salvaged
    /// ledger — stay exact even when an import fails.
    pub fn reject(&self, snap: &SeqSnapshot) {
        let mut g = self.inner.lock().unwrap();
        g.claimed = g.claimed.saturating_sub(1);
        g.discarded += 1;
        g.tokens_claimed = g
            .tokens_claimed
            .saturating_sub(snap.salvaged_tokens() as u64);
    }

    /// Drop everything still queued (run shutdown), accounting it as
    /// deliberately discarded. Returns the number discarded.
    pub fn discard_all(&self) -> usize {
        let mut g = self.inner.lock().unwrap();
        let n = g.queue.len();
        g.queue.clear();
        g.discarded += n as u64;
        n
    }

    /// Snapshots currently awaiting an actor — the rollout-queue backlog.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn deposited(&self) -> u64 {
        self.inner.lock().unwrap().deposited
    }

    pub fn claimed(&self) -> u64 {
        self.inner.lock().unwrap().claimed
    }

    pub fn discarded(&self) -> u64 {
        self.inner.lock().unwrap().discarded
    }

    /// Wire-form deposits rejected at decode (byzantine injections,
    /// truncated transfers). A subset of `discarded`.
    pub fn corrupt_rejected(&self) -> u64 {
        self.inner.lock().unwrap().corrupt_rejected
    }

    /// Generated tokens deposited / claimed so far (salvage accounting).
    pub fn token_counts(&self) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.tokens_deposited, g.tokens_claimed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(seq_id: u64, gen: usize) -> SeqSnapshot {
        SeqSnapshot {
            seq_id,
            group_id: seq_id,
            problem_id: seq_id,
            prompt: vec![1, 2],
            gen_tokens: vec![5; gen],
            behavior_lp: vec![-0.5; gen],
            token_version: vec![1; gen],
            pos: if gen == 0 { 0 } else { 1 + gen },
            max_new: 32,
            rng_words: [0; 4],
            t_start: 0.0,
        }
    }

    #[test]
    fn deposit_claim_conservation() {
        let hub = MigrationHub::new();
        assert_eq!(hub.deposit(vec![snap(1, 3), snap(2, 0), snap(3, 5)]), 3);
        assert_eq!(hub.depth(), 3);
        let got = hub.claim(2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].seq_id, 1, "FIFO: oldest orphan first");
        assert_eq!(hub.discard_all(), 1);
        assert_eq!(hub.claim(5).len(), 0);
        assert_eq!(
            (hub.deposited(), hub.claimed(), hub.discarded(), hub.depth()),
            (3, 2, 1, 0),
            "deposited == claimed + discarded + depth"
        );
        let (dep_tok, cl_tok) = hub.token_counts();
        assert_eq!(dep_tok, 8);
        assert_eq!(cl_tok, 3, "seq 1 and 2 claimed: 3 + 0 tokens");
    }

    #[test]
    fn reject_moves_books_from_claimed_to_discarded() {
        let hub = MigrationHub::new();
        hub.deposit(vec![snap(1, 4), snap(2, 2)]);
        let got = hub.claim(2);
        hub.reject(&got[0]);
        assert_eq!(
            (hub.deposited(), hub.claimed(), hub.discarded(), hub.depth()),
            (2, 1, 1, 0),
            "rejection keeps deposited == claimed + discarded + depth"
        );
        let (dep, cl) = hub.token_counts();
        assert_eq!((dep, cl), (6, 2), "rejected tokens leave the salvage ledger");
    }

    #[test]
    fn raw_deposits_decode_at_claim_and_corrupt_ones_are_rejected() {
        let hub = MigrationHub::new();
        let good = snap(1, 3);
        hub.deposit_raw(good.to_bytes());
        // bit-flipped + truncated PRLSNAP1 bytes: the byzantine shape
        let mut bad = snap(2, 5).to_bytes();
        bad[3] ^= 0x40;
        bad.truncate(bad.len() - 2);
        hub.deposit_raw(bad);
        assert_eq!(hub.depth(), 2);

        let got = hub.claim(10);
        assert_eq!(got.len(), 1, "only the intact deposit reaches a claimer");
        assert_eq!(got[0], good);
        assert_eq!(
            (hub.deposited(), hub.claimed(), hub.discarded(), hub.depth()),
            (2, 1, 1, 0),
            "corrupt deposit lands in discarded; books balance"
        );
        assert_eq!(hub.corrupt_rejected(), 1);
        let (dep, cl) = hub.token_counts();
        assert_eq!((dep, cl), (3, 3), "corrupt bytes contribute no phantom tokens");
    }

    #[test]
    fn corrupt_entries_do_not_count_against_claim_max() {
        let hub = MigrationHub::new();
        hub.deposit_raw(vec![0xff; 16]); // garbage ahead of real work
        hub.deposit(vec![snap(1, 2)]);
        let got = hub.claim(1);
        assert_eq!(got.len(), 1, "the reject is skipped, the claim still fills");
        assert_eq!(hub.corrupt_rejected(), 1);
    }

    #[test]
    fn claim_respects_max_and_empty() {
        let hub = MigrationHub::new();
        assert!(hub.claim(4).is_empty());
        hub.deposit(vec![snap(1, 1)]);
        assert_eq!(hub.claim(0).len(), 0);
        assert_eq!(hub.claim(10).len(), 1);
    }

    #[test]
    fn concurrent_deposit_claim_loses_nothing() {
        use std::sync::Arc;
        let hub = Arc::new(MigrationHub::new());
        let mut hands = Vec::new();
        for a in 0..4u64 {
            let hub = hub.clone();
            hands.push(std::thread::spawn(move || {
                for i in 0..50 {
                    hub.deposit(vec![snap(a * 1000 + i, 2)]);
                }
            }));
        }
        let claimer = {
            let hub = hub.clone();
            std::thread::spawn(move || {
                let mut got = 0usize;
                while got < 200 {
                    got += hub.claim(7).len();
                }
                got
            })
        };
        for h in hands {
            h.join().unwrap();
        }
        assert_eq!(claimer.join().unwrap(), 200);
        assert_eq!(hub.deposited(), 200);
        assert_eq!(hub.claimed(), 200);
        assert_eq!(hub.depth(), 0);
    }
}
