//! Admission scheduling and preemption policies (extracted from
//! `Engine::admit` / `Engine::step`).
//!
//! The engine owns a fixed pool of decode slots and a queue of pending
//! sequences; whenever a slot is free it asks the scheduler which queued
//! sequence to admit. The scheduler also owns the KV-block gate that used
//! to be inlined in the engine: `can_admit(&SeqView)` reports whether the
//! paged allocator can hold that sequence *right now* (a view-based gate,
//! because the cost depends on more than length — a fresh group member
//! sharing a registered prompt prefix costs zero new blocks), and a
//! policy that returns `None` leaves the slot empty this round
//! (admission backpressure — the vLLM-style "wait for a release").
//!
//! Since the shared-prefix/preemption refactor the scheduler also owns
//! **eviction**: when a running sequence cannot grow (the allocator's
//! block-pressure signal), the engine asks [`Scheduler::pick_victim`]
//! which active sequence to preempt. The victim is parked through the
//! [`super::SeqSnapshot`] path — blocks freed, re-admitted later through
//! a coalesced replay — instead of the slot just stalling. The
//! [`PreemptPolicy`] (config `[kv] preempt_policy`) selects the victim
//! rule; `none` reproduces the legacy stall-in-place behavior exactly.

/// Read-only view of one queued or active sequence, handed to scheduling
/// policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeqView {
    pub seq_id: u64,
    pub group_id: u64,
    /// current stream length (BOS + prompt + generated prefix) — what the
    /// KV allocator must be able to hold at admission
    pub total_len: usize,
    /// generated-prefix length (> 0 only for imported snapshots and
    /// preempted-and-parked sequences)
    pub gen_len: usize,
    /// cache positions already fed (tokens consumed by decode so far).
    /// `pos > 0` marks a sequence whose KV prefix must be replayed after
    /// seating — the admission gate uses it to hold replay candidates for
    /// the coalesced window while letting fresh (`pos == 0`) sequences
    /// admit freely
    pub pos: usize,
    /// KV blocks the sequence holds in the paged allocator — the eviction
    /// cost signal: parking frees this many block refs, and a resume must
    /// re-seat (and under the paged device layout, per-row replay) the
    /// same count. For sequences not yet seated this is the block cost of
    /// admitting them (`ceil(total_len / block_size)` before sharing).
    pub kv_blocks: usize,
}

/// Device-side KV cache layout (`[kv] layout`).
///
/// `Dense` keeps the cache as one `[L, 2, B, max_seq, H, hd]` tensor with
/// a slot axis — every slot owns a full `max_seq` stripe whether it uses
/// it or not. `Paged` addresses a block pool
/// `[n_blocks, L, 2, block_size, H, hd]` through per-row block tables, so
/// device memory follows the allocator's paged accounting (prefix sharing
/// and preemption actually return device blocks). Dense stays the default
/// until paged parity is proven on the target runtime; the decode graphs
/// for both layouts ship in every artifact set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvLayout {
    /// per-slot dense cache tensor (the legacy layout, bit-for-bit)
    #[default]
    Dense,
    /// block-indexed pool + per-row block tables (`decode_paged` graph)
    Paged,
}

impl KvLayout {
    pub fn name(&self) -> &'static str {
        match self {
            KvLayout::Dense => "dense",
            KvLayout::Paged => "paged",
        }
    }

    pub fn parse(s: &str) -> Option<KvLayout> {
        match s {
            "dense" => Some(KvLayout::Dense),
            "paged" => Some(KvLayout::Paged),
            _ => None,
        }
    }
}

/// Victim-selection rule for scheduler-driven preemption under KV block
/// pressure (`[kv] preempt_policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PreemptPolicy {
    /// never preempt: a sequence that cannot grow stalls its slot in
    /// place (the legacy behavior, bit-for-bit)
    #[default]
    None,
    /// park the active sequence with the fewest generated tokens — the
    /// least salvaged work lost and the cheapest replay on resume
    /// (vLLM preempts the latest-arrived for the same reason)
    Youngest,
}

impl PreemptPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            PreemptPolicy::None => "none",
            PreemptPolicy::Youngest => "youngest",
        }
    }

    pub fn parse(s: &str) -> Option<PreemptPolicy> {
        match s {
            "none" => Some(PreemptPolicy::None),
            "youngest" => Some(PreemptPolicy::Youngest),
            _ => None,
        }
    }

    /// Shared victim rule used by the built-in schedulers. After the
    /// salvage cost (`gen_len`), ties break on `kv_blocks` — the actual
    /// replay bill: parking a sequence frees that many block refs and a
    /// resume must re-seat and replay exactly that many, so among equal
    /// salvage losses the cheapest-to-restore victim wins. When every
    /// view reports `kv_blocks = ceil(total_len / bs)` (the engine's
    /// default fill) the key is order-equivalent to the historical
    /// `(gen_len, total_len, seq_id)` — block counts are monotone in
    /// length — so existing digests are unchanged. Final tie-break is the
    /// sequence's *local id*, not its slot index: slot placement depends
    /// on admission interleaving (which slot freed first), so an index
    /// tie-break would pick different victims across otherwise-identical
    /// runs — the id makes victim choice a pure function of the sequence
    /// set, which is what replay-stable chaos runs
    /// (tests/determinism.rs) assert.
    ///
    /// Public because external preemption uses it directly: the serving
    /// gateway's QoS eviction (interactive traffic displacing batch
    /// rollouts) runs this rule over a *class-filtered* view set, so the
    /// victim choice is the same deterministic function whether the
    /// pressure came from KV blocks or from a latency-sensitive arrival.
    pub fn pick(&self, active: &[SeqView]) -> Option<usize> {
        match self {
            PreemptPolicy::None => None,
            PreemptPolicy::Youngest => active
                .iter()
                .enumerate()
                .min_by_key(|(_, v)| (v.gen_len, v.kv_blocks, v.total_len, v.seq_id))
                .map(|(i, _)| i),
        }
    }
}

/// An admission policy: picks which pending sequence enters the next free
/// decode slot, and which active sequence to evict under block pressure.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Pick the queue index of the sequence to admit into the next free
    /// slot, or `None` to leave the slot empty this round.
    /// `can_admit` is the live KV-block gate (share-aware: cost depends
    /// on the whole view, not just length).
    fn pick(
        &mut self,
        pending: &[SeqView],
        can_admit: &dyn Fn(&SeqView) -> bool,
    ) -> Option<usize>;

    /// Under block pressure — the sequence at `active[stalled]` cannot
    /// grow — pick the index (into `active`) of the sequence to preempt:
    /// it is parked (blocks freed, re-queued through the snapshot path)
    /// so the rest can make progress. `None` stalls the slot in place
    /// (the legacy behavior, and the default).
    fn pick_victim(&mut self, _active: &[SeqView], _stalled: usize) -> Option<usize> {
        None
    }
}

/// The legacy policy, bit-for-bit: admit the queue head, and if the head
/// cannot get KV blocks, admit nothing (head-of-line blocking — arrival
/// order is completion-fairness under uniform lengths).
#[derive(Debug, Default)]
pub struct Fifo {
    pub preempt: PreemptPolicy,
}

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick(
        &mut self,
        pending: &[SeqView],
        can_admit: &dyn Fn(&SeqView) -> bool,
    ) -> Option<usize> {
        let head = pending.first()?;
        if can_admit(head) {
            Some(0)
        } else {
            None
        }
    }

    fn pick_victim(&mut self, active: &[SeqView], _stalled: usize) -> Option<usize> {
        self.preempt.pick(active)
    }
}

/// Longest-generated-prefix first: among admissible queued sequences,
/// prefer the one with the most already-generated tokens (ties broken by
/// total length, then queue order — deterministic).
///
/// Rationale: a migrated snapshot's prefix tokens were sampled under old
/// weight versions; every decode round it spends queued adds one more
/// optimizer step of lag to *all* of them. Admitting the longest salvaged
/// prefix first minimizes the total extra lag across salvaged tokens, and
/// also frees its KV blocks soonest (it is closest to finishing). Unlike
/// [`Fifo`], an inadmissible head does not block shorter sequences behind
/// it.
#[derive(Debug, Default)]
pub struct LongestPrefixFirst {
    pub preempt: PreemptPolicy,
}

impl Scheduler for LongestPrefixFirst {
    fn name(&self) -> &'static str {
        "longest_prefix"
    }

    fn pick(
        &mut self,
        pending: &[SeqView],
        can_admit: &dyn Fn(&SeqView) -> bool,
    ) -> Option<usize> {
        let mut best: Option<(usize, SeqView)> = None;
        for (i, v) in pending.iter().enumerate() {
            if !can_admit(v) {
                continue;
            }
            let better = match &best {
                None => true,
                Some((_, b)) => {
                    v.gen_len > b.gen_len || (v.gen_len == b.gen_len && v.total_len > b.total_len)
                }
            };
            if better {
                best = Some((i, *v));
            }
        }
        best.map(|(i, _)| i)
    }

    fn pick_victim(&mut self, active: &[SeqView], _stalled: usize) -> Option<usize> {
        self.preempt.pick(active)
    }
}

/// Config-level selector for the admission policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    #[default]
    Fifo,
    LongestPrefixFirst,
}

impl SchedPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::Fifo => "fifo",
            SchedPolicy::LongestPrefixFirst => "longest_prefix",
        }
    }

    /// Instantiate the policy with the legacy stall-in-place preemption.
    pub fn build(&self) -> Box<dyn Scheduler> {
        self.build_with_preempt(PreemptPolicy::None)
    }

    /// Instantiate the policy with a victim rule for block-pressure
    /// preemption.
    pub fn build_with_preempt(&self, preempt: PreemptPolicy) -> Box<dyn Scheduler> {
        match self {
            SchedPolicy::Fifo => Box::new(Fifo { preempt }),
            SchedPolicy::LongestPrefixFirst => Box::new(LongestPrefixFirst { preempt }),
        }
    }

    pub fn parse(s: &str) -> Option<SchedPolicy> {
        match s {
            "fifo" => Some(SchedPolicy::Fifo),
            "longest_prefix" => Some(SchedPolicy::LongestPrefixFirst),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(seq_id: u64, total_len: usize, gen_len: usize) -> SeqView {
        // default fill mirrors the engine: block cost monotone in length
        // (block_size 4), so these views exercise the historical ordering
        SeqView {
            seq_id,
            group_id: seq_id,
            total_len,
            gen_len,
            // resumed sequences sit one short of their stream length
            pos: if gen_len > 0 { total_len - 1 } else { 0 },
            kv_blocks: total_len.div_ceil(4),
        }
    }

    #[test]
    fn fifo_admits_head_only() {
        let mut s = Fifo::default();
        let q = vec![view(1, 10, 0), view(2, 3, 0)];
        assert_eq!(s.pick(&q, &|_| true), Some(0));
        // head too long for the pool: nothing admitted even though the
        // second sequence would fit (legacy head-of-line semantics)
        assert_eq!(s.pick(&q, &|v| v.total_len <= 5), None);
        assert_eq!(s.pick(&[], &|_| true), None);
    }

    #[test]
    fn longest_prefix_prefers_salvaged_work() {
        let mut s = LongestPrefixFirst::default();
        let q = vec![view(1, 10, 0), view(2, 14, 6), view(3, 12, 6), view(4, 9, 2)];
        // gen_len 6 twice: the longer total wins
        assert_eq!(s.pick(&q, &|_| true), Some(1));
        // block the winner: next-best admissible
        assert_eq!(s.pick(&q, &|v| v.total_len < 14), Some(2));
        // only fresh prompts fit
        assert_eq!(s.pick(&q, &|v| v.total_len <= 10), Some(3));
        assert_eq!(s.pick(&q, &|_| false), None);
    }

    #[test]
    fn longest_prefix_ties_break_by_queue_order() {
        let mut s = LongestPrefixFirst::default();
        let q = vec![view(7, 10, 3), view(8, 10, 3)];
        assert_eq!(s.pick(&q, &|_| true), Some(0));
    }

    #[test]
    fn gate_sees_the_whole_view_not_just_length() {
        // share-aware admission: the gate can admit a group member whose
        // prompt blocks are already registered even when a same-length
        // stranger would not fit
        let mut s = LongestPrefixFirst::default();
        let q = vec![view(1, 40, 0), view(2, 40, 0)];
        let pick = s.pick(&q, &|v| v.seq_id == 2);
        assert_eq!(pick, Some(1));
    }

    #[test]
    fn preempt_none_stalls_in_place() {
        let mut f = Fifo::default();
        let active = vec![view(1, 10, 4), view(2, 8, 1)];
        assert_eq!(f.pick_victim(&active, 0), None, "legacy: no eviction");
        let mut l = LongestPrefixFirst::default();
        assert_eq!(l.pick_victim(&active, 0), None);
    }

    #[test]
    fn preempt_youngest_picks_least_salvage() {
        let mut s = Fifo { preempt: PreemptPolicy::Youngest };
        let active = vec![view(1, 20, 9), view(2, 12, 2), view(3, 30, 2)];
        // gen_len tie at 2: the shorter total (cheapest replay) wins
        assert_eq!(s.pick_victim(&active, 0), Some(1));
        // the stalled sequence itself is a legitimate victim
        let active = vec![view(1, 20, 0), view(2, 12, 5)];
        assert_eq!(s.pick_victim(&active, 0), Some(0));
    }

    #[test]
    fn preempt_youngest_tiebreak_is_admission_order_invariant() {
        // regression: identical (gen_len, total_len) ties used to break on
        // the slot index, so the victim depended on which slot each
        // sequence happened to land in. The id tie-break makes the choice
        // a pure function of the sequence set: every permutation of the
        // active array must name the same victim sequence.
        let mut s = Fifo { preempt: PreemptPolicy::Youngest };
        let a =
            SeqView { seq_id: 31, group_id: 1, total_len: 12, gen_len: 2, pos: 11, kv_blocks: 3 };
        let b =
            SeqView { seq_id: 17, group_id: 2, total_len: 12, gen_len: 2, pos: 11, kv_blocks: 3 };
        let c =
            SeqView { seq_id: 54, group_id: 3, total_len: 12, gen_len: 2, pos: 11, kv_blocks: 3 };
        let perms: [[SeqView; 3]; 6] = [
            [a, b, c], [a, c, b], [b, a, c], [b, c, a], [c, a, b], [c, b, a],
        ];
        for p in perms {
            let vi = s.pick_victim(&p, 0).expect("youngest always names a victim");
            assert_eq!(
                p[vi].seq_id, 17,
                "victim must be the lowest-id tied sequence regardless of slot order"
            );
        }
    }

    #[test]
    fn preempt_youngest_breaks_salvage_ties_on_block_cost() {
        // two sequences with identical salvage loss (gen_len) but
        // different allocator bills: the shared-prefix member holds fewer
        // private blocks than the equally-long stranger, so it is the
        // cheaper eviction even though its total_len is *larger* — the
        // block-count signal must dominate the length tie-break
        let mut s = Fifo { preempt: PreemptPolicy::Youngest };
        let shared =
            SeqView { seq_id: 9, group_id: 1, total_len: 20, gen_len: 3, pos: 19, kv_blocks: 2 };
        let stranger =
            SeqView { seq_id: 4, group_id: 2, total_len: 16, gen_len: 3, pos: 15, kv_blocks: 4 };
        assert_eq!(s.pick_victim(&[stranger, shared], 0), Some(1));
    }

    #[test]
    fn kv_layout_parse_and_names() {
        assert_eq!(KvLayout::parse("dense"), Some(KvLayout::Dense));
        assert_eq!(KvLayout::parse("paged"), Some(KvLayout::Paged));
        assert_eq!(KvLayout::parse("ragged"), None);
        assert_eq!(KvLayout::default(), KvLayout::Dense);
        assert_eq!(KvLayout::Paged.name(), "paged");
        assert_eq!(KvLayout::Dense.name(), "dense");
    }

    #[test]
    fn preempt_policy_parse_and_names() {
        assert_eq!(PreemptPolicy::parse("none"), Some(PreemptPolicy::None));
        assert_eq!(PreemptPolicy::parse("youngest"), Some(PreemptPolicy::Youngest));
        assert_eq!(PreemptPolicy::parse("oldest"), None);
        assert_eq!(PreemptPolicy::default(), PreemptPolicy::None);
        assert_eq!(PreemptPolicy::Youngest.name(), "youngest");
    }

    #[test]
    fn policy_parse_and_build() {
        assert_eq!(SchedPolicy::parse("fifo"), Some(SchedPolicy::Fifo));
        assert_eq!(
            SchedPolicy::parse("longest_prefix"),
            Some(SchedPolicy::LongestPrefixFirst)
        );
        assert_eq!(SchedPolicy::parse("srpt"), None);
        assert_eq!(SchedPolicy::Fifo.build().name(), "fifo");
        assert_eq!(
            SchedPolicy::LongestPrefixFirst.build().name(),
            "longest_prefix"
        );
        assert_eq!(SchedPolicy::default(), SchedPolicy::Fifo);
        // built-with-preempt schedulers actually evict
        let mut s = SchedPolicy::Fifo.build_with_preempt(PreemptPolicy::Youngest);
        assert!(s.pick_victim(&[view(1, 4, 0), view(2, 5, 1)], 1).is_some());
        let mut s = SchedPolicy::Fifo.build();
        assert!(s.pick_victim(&[view(1, 4, 0), view(2, 5, 1)], 1).is_none());
    }
}
