//! Admission scheduling policies (extracted from `Engine::admit`).
//!
//! The engine owns a fixed pool of decode slots and a queue of pending
//! sequences; whenever a slot is free it asks the scheduler which queued
//! sequence to admit. The scheduler also owns the KV-block gate that used
//! to be inlined in the engine: `can_admit(total_len)` reports whether
//! the paged allocator can hold a sequence of that length *right now*,
//! and a policy that returns `None` leaves the slot empty this round
//! (admission backpressure — the vLLM-style "wait for a release").
//!
//! Policies are deliberately stateless views over the queue: preemption
//! of *running* sequences stays with the engine (it stalls a slot whose
//! KV growth fails, vLLM-style), so a policy's whole contract is the
//! `pick` order.

/// Read-only view of one queued sequence, handed to scheduling policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeqView {
    pub seq_id: u64,
    pub group_id: u64,
    /// current stream length (BOS + prompt + generated prefix) — what the
    /// KV allocator must be able to hold at admission
    pub total_len: usize,
    /// generated-prefix length (> 0 only for imported snapshots)
    pub gen_len: usize,
}

/// An admission policy: picks which pending sequence enters the next free
/// decode slot.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Pick the queue index of the sequence to admit into the next free
    /// slot, or `None` to leave the slot empty this round.
    /// `can_admit(total_len)` is the live KV-block gate.
    fn pick(&mut self, pending: &[SeqView], can_admit: &dyn Fn(usize) -> bool) -> Option<usize>;
}

/// The legacy policy, bit-for-bit: admit the queue head, and if the head
/// cannot get KV blocks, admit nothing (head-of-line blocking — arrival
/// order is completion-fairness under uniform lengths).
#[derive(Debug, Default)]
pub struct Fifo;

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick(&mut self, pending: &[SeqView], can_admit: &dyn Fn(usize) -> bool) -> Option<usize> {
        let head = pending.first()?;
        if can_admit(head.total_len) {
            Some(0)
        } else {
            None
        }
    }
}

/// Longest-generated-prefix first: among admissible queued sequences,
/// prefer the one with the most already-generated tokens (ties broken by
/// total length, then queue order — deterministic).
///
/// Rationale: a migrated snapshot's prefix tokens were sampled under old
/// weight versions; every decode round it spends queued adds one more
/// optimizer step of lag to *all* of them. Admitting the longest salvaged
/// prefix first minimizes the total extra lag across salvaged tokens, and
/// also frees its KV blocks soonest (it is closest to finishing). Unlike
/// [`Fifo`], an inadmissible head does not block shorter sequences behind
/// it.
#[derive(Debug, Default)]
pub struct LongestPrefixFirst;

impl Scheduler for LongestPrefixFirst {
    fn name(&self) -> &'static str {
        "longest_prefix"
    }

    fn pick(&mut self, pending: &[SeqView], can_admit: &dyn Fn(usize) -> bool) -> Option<usize> {
        let mut best: Option<(usize, SeqView)> = None;
        for (i, v) in pending.iter().enumerate() {
            if !can_admit(v.total_len) {
                continue;
            }
            let better = match &best {
                None => true,
                Some((_, b)) => {
                    v.gen_len > b.gen_len || (v.gen_len == b.gen_len && v.total_len > b.total_len)
                }
            };
            if better {
                best = Some((i, *v));
            }
        }
        best.map(|(i, _)| i)
    }
}

/// Config-level selector for the admission policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    #[default]
    Fifo,
    LongestPrefixFirst,
}

impl SchedPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::Fifo => "fifo",
            SchedPolicy::LongestPrefixFirst => "longest_prefix",
        }
    }

    /// Instantiate the policy.
    pub fn build(&self) -> Box<dyn Scheduler> {
        match self {
            SchedPolicy::Fifo => Box::new(Fifo),
            SchedPolicy::LongestPrefixFirst => Box::new(LongestPrefixFirst),
        }
    }

    pub fn parse(s: &str) -> Option<SchedPolicy> {
        match s {
            "fifo" => Some(SchedPolicy::Fifo),
            "longest_prefix" => Some(SchedPolicy::LongestPrefixFirst),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(seq_id: u64, total_len: usize, gen_len: usize) -> SeqView {
        SeqView { seq_id, group_id: seq_id, total_len, gen_len }
    }

    #[test]
    fn fifo_admits_head_only() {
        let mut s = Fifo;
        let q = vec![view(1, 10, 0), view(2, 3, 0)];
        assert_eq!(s.pick(&q, &|_| true), Some(0));
        // head too long for the pool: nothing admitted even though the
        // second sequence would fit (legacy head-of-line semantics)
        assert_eq!(s.pick(&q, &|len| len <= 5), None);
        assert_eq!(s.pick(&[], &|_| true), None);
    }

    #[test]
    fn longest_prefix_prefers_salvaged_work() {
        let mut s = LongestPrefixFirst;
        let q = vec![view(1, 10, 0), view(2, 14, 6), view(3, 12, 6), view(4, 9, 2)];
        // gen_len 6 twice: the longer total wins
        assert_eq!(s.pick(&q, &|_| true), Some(1));
        // block the winner: next-best admissible
        assert_eq!(s.pick(&q, &|len| len < 14), Some(2));
        // only fresh prompts fit
        assert_eq!(s.pick(&q, &|len| len <= 10), Some(3));
        assert_eq!(s.pick(&q, &|_| false), None);
    }

    #[test]
    fn longest_prefix_ties_break_by_queue_order() {
        let mut s = LongestPrefixFirst;
        let q = vec![view(7, 10, 3), view(8, 10, 3)];
        assert_eq!(s.pick(&q, &|_| true), Some(0));
    }

    #[test]
    fn policy_parse_and_build() {
        assert_eq!(SchedPolicy::parse("fifo"), Some(SchedPolicy::Fifo));
        assert_eq!(
            SchedPolicy::parse("longest_prefix"),
            Some(SchedPolicy::LongestPrefixFirst)
        );
        assert_eq!(SchedPolicy::parse("srpt"), None);
        assert_eq!(SchedPolicy::Fifo.build().name(), "fifo");
        assert_eq!(
            SchedPolicy::LongestPrefixFirst.build().name(),
            "longest_prefix"
        );
        assert_eq!(SchedPolicy::default(), SchedPolicy::Fifo);
    }
}
