//! Discrete-event cluster simulator — the 128-GPU testbed substitute.
//!
//! Simulates N accelerators in the Appendix-A *flash* time unit, driven
//! by the calibrated [`crate::perfmodel::AccelModel`] utilization curve:
//! generation GPUs advance in decode rounds costing h/U(h) flashes (h =
//! live sequences on that GPU), the trainer consumes finished sequences
//! in optimizer batches costing tokens·τ/T flashes, and weight versions
//! propagate exactly like the real system's weight bus (in-flight for
//! PipelineRL, per-RL-step for Conventional).
//!
//! This regenerates the paper's *scale* results on a 1-core box:
//! Fig 2b (batch drain), Fig 2c (latency/throughput vs seqs per GPU),
//! Fig 3a (token-lag structure), Fig 5c (samples vs time at scale), and
//! cross-checks the analytic Fig 9 model with queueing effects included.
//!
//! The elastic tier is modeled too: with [`SimCfg::migrate`] a failed
//! GPU's in-flight sequences re-enter a regeneration queue with prefixes
//! intact (the cluster-scale mirror of `sched::SeqSnapshot` migration),
//! and [`SimAutoScale`] runs the real `sched::AutoScaler` policy on
//! simulated time to activate/retire spare generation GPUs from the
//! backlog/saturation signals — deterministically, so scale trajectories
//! replay per seed. `SimCfg::kv_blocks_per_gpu` adds the KV
//! memory-pressure model (the engine's paged allocator at cluster
//! scale): resident sequences consume blocks as they grow, admission is
//! block-gated, and an over-budget GPU preempts its youngest sequences
//! into the regen queue — so autoscale scenarios exercise
//! preemption-driven backlog on sim time. Conventional mode survives
//! churn too: dropped sequences refund the phase quota and regenerate
//! from scratch.

pub mod arrival;
pub mod scenarios;
pub mod sim;

pub use arrival::{due_at, poisson_trace, Arrival, ArrivalCfg};
pub use scenarios::{drain_scenario, generation_only, DrainPoint};
pub use sim::{GpuFailure, SimAutoScale, SimCfg, SimMode, SimResult, Simulator};
