//! Canned generation-only scenarios for Fig 2's analysis plots.

use crate::metrics::Series;
use crate::perfmodel::AccelModel;
use crate::util::Rng;

/// Result row for Fig 2c: time-to-finish and throughput when each GPU
/// must generate `seqs_per_gpu` sequences (batch slots = min(seqs, H)).
#[derive(Debug, Clone)]
pub struct DrainPoint {
    pub seqs_per_gpu: usize,
    pub time_flashes: f64,
    pub tokens_per_flash: f64,
}

/// Pure generation of a fixed set of sequences on one GPU with slot
/// count `h`: returns the live-batch trajectory (Fig 2b) and totals.
pub fn generation_only(
    accel: &AccelModel,
    h: usize,
    n_seqs: usize,
    l_max: usize,
    seed: u64,
) -> (Series, f64, f64) {
    let mut rng = Rng::with_stream(seed, 0xd2a1);
    let mut pending: Vec<usize> = (0..n_seqs).map(|_| 1 + rng.below(l_max)).collect();
    let mut slots: Vec<usize> = Vec::new();
    let mut t = 0.0;
    let mut tokens = 0.0;
    let mut series = Series::default();
    loop {
        while slots.len() < h {
            match pending.pop() {
                Some(len) => slots.push(len),
                None => break,
            }
        }
        if slots.is_empty() {
            break;
        }
        let active = slots.len();
        series.push(t, t, active as f64);
        t += active as f64 / accel.u(active);
        tokens += active as f64;
        slots.iter_mut().for_each(|r| *r -= 1);
        slots.retain(|&r| r > 0);
    }
    series.push(t, t, 0.0);
    (series, t, tokens / t.max(1e-9))
}

/// Fig 2c sweep: per-GPU sequence counts vs completion time/throughput.
pub fn drain_scenario(
    accel: &AccelModel,
    h: usize,
    l_max: usize,
    counts: &[usize],
) -> Vec<DrainPoint> {
    counts
        .iter()
        .map(|&n| {
            let (_, t, thr) = generation_only(accel, h.min(n), n, l_max, 7);
            DrainPoint { seqs_per_gpu: n, time_flashes: t, tokens_per_flash: thr }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_drains_to_zero() {
        let accel = AccelModel::h100();
        let (series, t, thr) = generation_only(&accel, 64, 256, 512, 3);
        assert!(t > 0.0 && thr > 0.0);
        let vals = series.values();
        assert_eq!(*vals.last().unwrap(), 0.0);
        assert_eq!(vals[0], 64.0);
        // the tail (few live sequences) exists — Fig 2b's inefficiency
        assert!(vals.iter().any(|&v| v > 0.0 && v <= 8.0));
    }

    #[test]
    fn time_plateaus_as_counts_shrink() {
        // Fig 2c: halving the sequences per GPU does NOT halve the time —
        // the longest sequence dominates.
        let accel = AccelModel::h100();
        let pts = drain_scenario(&accel, 256, 512, &[32, 64, 128, 256]);
        let t32 = pts[0].time_flashes;
        let t256 = pts[3].time_flashes;
        assert!(
            t256 / t32 < 8.0 / 2.0,
            "8x the work should take well under 4x the time: {} vs {}",
            t32,
            t256
        );
        // throughput grows with more sequences per GPU
        assert!(pts[3].tokens_per_flash > pts[0].tokens_per_flash);
    }
}
