//! Open-loop arrival models for the serving gateway (ROADMAP direction
//! 1: "simcluster gets an open-loop arrival model — millions of users =
//! Poisson/bursty traces — to prove SLOs under churn").
//!
//! The gateway's acceptance scenario needs *external* traffic that does
//! not wait for the system (open loop): request arrival times are drawn
//! up front from a seeded process, and the driver submits whatever the
//! trace says is due at each tick regardless of how backed up the
//! gateway is. Two processes cover the paper-style serving story:
//!
//! * **Poisson** — memoryless steady-state load: exponential
//!   inter-arrival gaps `-ln(U)/rate` accumulated over continuous time,
//!   floored onto the gateway's integer tick clock.
//! * **Bursty** — the same Poisson base with periodic burst windows in
//!   which the rate is multiplied (flash crowds). This is the trace that
//!   must show interactive p99 admission-to-first-token holding its SLO
//!   while batch rollouts degrade gracefully and recover after the
//!   window closes.
//!
//! Traces are deterministic per seed (PCG64 stream, see
//! [`crate::util::Rng`]) so SLO numbers replay bit-for-bit in tests and
//! in `benches/gateway.rs`.

use crate::util::Rng;

/// One scheduled request arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    /// gateway tick (step count) the request becomes due
    pub tick: u64,
    /// external tenant id (never `ROLLOUT_TENANT`; see [`ArrivalCfg`])
    pub tenant: u64,
}

/// Parameters of an open-loop trace.
#[derive(Debug, Clone, Copy)]
pub struct ArrivalCfg {
    /// mean arrivals per tick outside burst windows (> 0)
    pub rate: f64,
    /// horizon in ticks: arrivals are generated in [0, horizon)
    pub horizon: u64,
    /// arrivals rotate over this many external tenants (ids 1..=tenants)
    pub tenants: u64,
    /// every `burst_every` ticks a burst window opens (0 = pure Poisson)
    pub burst_every: u64,
    /// burst window length in ticks
    pub burst_len: u64,
    /// rate multiplier inside a burst window (>= 1)
    pub burst_mult: f64,
}

impl Default for ArrivalCfg {
    fn default() -> Self {
        ArrivalCfg {
            rate: 0.2,
            horizon: 200,
            tenants: 4,
            burst_every: 0,
            burst_len: 0,
            burst_mult: 1.0,
        }
    }
}

impl ArrivalCfg {
    /// Is `tick` inside a burst window?
    pub fn in_burst(&self, tick: u64) -> bool {
        self.burst_every > 0 && self.burst_len > 0 && tick % self.burst_every < self.burst_len
    }
}

/// Draw a full open-loop trace: arrival ticks sorted ascending, tenants
/// rotating 1..=tenants. Deterministic per (cfg, seed).
///
/// The thinning construction: gaps are drawn from the *burst* (maximum)
/// rate, and candidates landing outside a burst window survive with
/// probability `1/burst_mult` — the standard way to sample an
/// inhomogeneous Poisson process without inverting its rate integral,
/// and it degenerates to plain Poisson when no bursts are configured.
pub fn poisson_trace(cfg: &ArrivalCfg, seed: u64) -> Vec<Arrival> {
    assert!(cfg.rate > 0.0 && cfg.rate.is_finite(), "rate must be positive");
    assert!(cfg.burst_mult >= 1.0, "burst_mult must be >= 1");
    let mut rng = Rng::with_stream(seed, 0x0a55_71a1_a77e_57a7);
    let peak = cfg.rate * cfg.burst_mult;
    let mut t = 0.0f64;
    let mut out = Vec::new();
    let mut tenant = 0u64;
    loop {
        // exponential gap at the peak rate; max() guards ln(0)
        let u = rng.f64().max(f64::MIN_POSITIVE);
        t += -u.ln() / peak;
        let tick = t.floor() as u64;
        if tick >= cfg.horizon {
            break;
        }
        // thinning: off-burst candidates survive at rate/peak
        if !cfg.in_burst(tick) && rng.f64() >= 1.0 / cfg.burst_mult {
            continue;
        }
        tenant = tenant % cfg.tenants.max(1) + 1;
        out.push(Arrival { tick, tenant });
    }
    out
}

/// Arrivals due at exactly `tick` (the per-step drain for an open-loop
/// driver walking a sorted trace with an advancing cursor).
pub fn due_at(trace: &[Arrival], cursor: &mut usize, tick: u64) -> Vec<Arrival> {
    let start = *cursor;
    while *cursor < trace.len() && trace[*cursor].tick <= tick {
        *cursor += 1;
    }
    trace[start..*cursor].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_sorted() {
        let cfg = ArrivalCfg { rate: 0.5, horizon: 400, ..ArrivalCfg::default() };
        let a = poisson_trace(&cfg, 42);
        let b = poisson_trace(&cfg, 42);
        assert_eq!(a, b, "same seed, same trace");
        assert_ne!(a, poisson_trace(&cfg, 43), "different seed, different trace");
        assert!(a.windows(2).all(|w| w[0].tick <= w[1].tick), "sorted");
        assert!(a.iter().all(|x| x.tick < 400 && (1..=4).contains(&x.tenant)));
        // mean ~ rate * horizon = 200; a loose 3-sigma-ish band
        assert!(a.len() > 120 && a.len() < 300, "got {}", a.len());
    }

    #[test]
    fn bursts_concentrate_arrivals() {
        let cfg = ArrivalCfg {
            rate: 0.2,
            horizon: 1000,
            tenants: 2,
            burst_every: 100,
            burst_len: 20,
            burst_mult: 8.0,
        };
        let trace = poisson_trace(&cfg, 7);
        let in_burst = trace.iter().filter(|a| cfg.in_burst(a.tick)).count();
        let out_burst = trace.len() - in_burst;
        // burst windows are 20% of the horizon at 8x the rate: they must
        // hold the clear majority of arrivals
        assert!(
            in_burst > out_burst,
            "bursts should dominate: {in_burst} in vs {out_burst} out"
        );
        assert!(!trace.is_empty());
    }

    #[test]
    fn due_at_walks_the_trace_exactly_once() {
        let cfg = ArrivalCfg { rate: 0.3, horizon: 100, ..ArrivalCfg::default() };
        let trace = poisson_trace(&cfg, 11);
        let mut cursor = 0usize;
        let mut seen = 0usize;
        for tick in 0..cfg.horizon {
            let due = due_at(&trace, &mut cursor, tick);
            assert!(due.iter().all(|a| a.tick <= tick));
            seen += due.len();
        }
        assert_eq!(seen, trace.len(), "every arrival delivered exactly once");
    }
}
