//! The simulator core (see module docs).

use crate::metrics::Series;
use crate::perfmodel::AccelModel;
use crate::sched::{AutoScaleCfg, AutoScaler, ScaleDecision, ScaleSignals};
use crate::testkit::golden::{DigestEvent, EventLog, RunDigest};
use crate::util::Rng;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    Pipeline,
    Conventional { g: usize },
}

/// A generation-GPU outage: at flash `at`, `gpu` drops every live
/// sequence and generates nothing until `at + down_for` (generator
/// churn, LlamaRL-style). Pipeline mode refills and keeps training.
/// Conventional mode refunds the dropped sequences' quota — they
/// regenerate *from scratch* once capacity recovers (the phase barrier
/// cannot salvage partial work), so the drain still completes; the lost
/// progress lands in `seqs_lost`. With [`SimCfg::migrate`] (pipeline
/// only) the dropped sequences instead re-enter the regeneration queue
/// with prefixes intact.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuFailure {
    pub gpu: usize,
    /// outage start (flashes)
    pub at: f64,
    /// outage duration (flashes)
    pub down_for: f64,
}

/// A control-plane pause window: from `at` until `at + hold_for` every
/// generation GPU parks *in place* — live sequences stay resident with
/// their prefixes and version runs intact, decode rounds reschedule at
/// the window end, and nothing is dropped or migrated (contrast
/// [`GpuFailure`], which evicts). The supervisor's
/// `RunCommand::Pause`/`Resume` on sim time: the trainer keeps draining
/// whatever finished before the pause, generation resumes exactly where
/// it stopped.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PauseWindow {
    /// pause start (flashes)
    pub at: f64,
    /// pause duration (flashes)
    pub hold_for: f64,
}

/// Autoscaling for the simulated generation tier: the real
/// [`AutoScaler`] policy, evaluated on simulated time, driving spare-GPU
/// activation/retirement — the cluster-scale mirror of the supervisor's
/// actor-pool resize.
#[derive(Debug, Clone)]
pub struct SimAutoScale {
    pub cfg: AutoScaleCfg,
    /// spare generation GPUs beyond `n_gen_gpus` the scaler may activate
    pub max_extra_gpus: usize,
    /// evaluation cadence in flashes (the supervisor-poll analogue)
    pub eval_every_flashes: f64,
    /// modeled trainer-inbox capacity the supply-saturation fraction is
    /// measured against (the rollout-topic capacity analogue)
    pub supply_capacity: usize,
}

#[derive(Debug, Clone)]
pub struct SimCfg {
    pub mode: SimMode,
    /// generation GPUs (pipeline: I; conventional: all N generate)
    pub n_gen_gpus: usize,
    /// training GPUs (pipeline: N − I; conventional: all N train)
    pub n_train_gpus: usize,
    /// generation slots per GPU (paper's H)
    pub slots_per_gpu: usize,
    /// sequences per optimizer batch (B)
    pub batch_b: usize,
    /// max sequence length; lengths ~ Uniform{1..=L}
    pub l_max: usize,
    /// train flashes per token
    pub tau: f64,
    pub accel: AccelModel,
    /// optimizer steps to run
    pub rl_steps: usize,
    pub seed: u64,
    /// flashes each generation GPU pauses per in-flight weight update
    pub weight_update_pause: f64,
    /// injected generation-GPU outages (empty = healthy cluster)
    pub failures: Vec<GpuFailure>,
    /// control-plane pause windows (generation parks in place, nothing
    /// is dropped; empty = never paused)
    pub pauses: Vec<PauseWindow>,
    /// partial-rollout migration: sequences dropped by outages (or a
    /// retired spare GPU) re-enter the regeneration queue with their
    /// generated prefixes and version runs intact, instead of counting
    /// as `seqs_lost` (pipeline mode only)
    pub migrate: bool,
    /// signal-driven spare-GPU autoscaling (requires `migrate`)
    pub autoscale: Option<SimAutoScale>,
    /// KV page size (tokens per block) for the memory-pressure model
    pub kv_block_size: usize,
    /// per-GPU KV block budget (None = unbounded, the legacy model).
    /// A resident sequence consumes ceil((progress+1)/kv_block_size)
    /// blocks, growing as it decodes; when a GPU's demand outgrows the
    /// budget it preempts its *youngest* (least-progressed) sequences
    /// into the regen queue — the engine's scheduler-driven preemption on
    /// sim time — and admission respects the remaining headroom, so
    /// memory pressure feeds the autoscaler's backlog signal. Requires
    /// pipeline + `migrate` (preempted prefixes must survive).
    pub kv_blocks_per_gpu: Option<usize>,
    /// emit the golden-run digest events (`testkit::golden`) on sim time:
    /// per-round tokens with version tags in canonical sequence-id order,
    /// sequence completions, optimizer steps and publishes, folded into
    /// `SimResult::digest`. The same replay-stability vocabulary as the
    /// token-level harness, at cluster scale.
    pub digest: bool,
}

impl SimCfg {
    pub fn pipeline(n: usize, i: usize, h: usize, b: usize, l: usize) -> Self {
        SimCfg {
            mode: SimMode::Pipeline,
            n_gen_gpus: i,
            n_train_gpus: n - i,
            slots_per_gpu: h,
            batch_b: b,
            l_max: l,
            tau: 4.92,
            accel: AccelModel::h100(),
            rl_steps: 50,
            seed: 0,
            weight_update_pause: 0.0,
            failures: Vec::new(),
            pauses: Vec::new(),
            migrate: false,
            autoscale: None,
            kv_block_size: 16,
            kv_blocks_per_gpu: None,
            digest: false,
        }
    }

    pub fn conventional(n: usize, g: usize, h: usize, b: usize, l: usize) -> Self {
        SimCfg {
            mode: SimMode::Conventional { g },
            n_gen_gpus: n,
            n_train_gpus: n,
            slots_per_gpu: h,
            batch_b: b,
            l_max: l,
            tau: 4.92,
            accel: AccelModel::h100(),
            rl_steps: 50,
            seed: 0,
            weight_update_pause: 0.0,
            failures: Vec::new(),
            pauses: Vec::new(),
            migrate: false,
            autoscale: None,
            kv_block_size: 16,
            kv_blocks_per_gpu: None,
            digest: false,
        }
    }

    /// Seed-derived churn: `n` outages of `down_for` flashes each, at
    /// deterministic GPUs/times in `[0, t_max)`. Same seed, same churn.
    pub fn with_churn(mut self, seed: u64, n: usize, t_max: f64, down_for: f64) -> Self {
        let mut rng = Rng::with_stream(seed, 0xfa11);
        for _ in 0..n {
            self.failures.push(GpuFailure {
                gpu: rng.below(self.n_gen_gpus.max(1)),
                at: rng.f64() * t_max,
                down_for,
            });
        }
        self
    }
}

#[derive(Debug, Clone)]
struct Seq {
    /// stable sequence id (survives migration/preemption) — the digest's
    /// canonical ordering key
    uid: u64,
    remaining: usize,
    /// (version, count) runs of generated tokens
    versions: Vec<(u64, usize)>,
    total: usize,
}

#[derive(Debug, Default, Clone)]
pub struct SimResult {
    /// (t, samples trained) per optimizer step — Fig 5c
    pub samples_vs_time: Series,
    /// (t, live sequences on GPU 0) — Fig 2b
    pub gpu0_active: Series,
    /// max token lag per optimizer step — Fig 6a analogue
    pub max_lag: Series,
    /// mean token lag per optimizer step
    pub mean_lag: Series,
    /// mean lag per relative token position (16 buckets) — Fig 3a
    pub lag_by_relpos: Vec<f64>,
    /// total tokens generated
    pub tokens: f64,
    /// end-to-end tokens/flash
    pub throughput: f64,
    /// wall time (flashes) at completion
    pub t_end: f64,
    /// sequences dropped by injected GPU outages (migration off)
    pub seqs_lost: usize,
    /// sequences handed to the regeneration queue with prefixes intact
    /// (outages and retired spares, migration on; re-migrations count)
    pub seqs_migrated: usize,
    /// sequences preempted by the KV memory-pressure model (youngest
    /// parked into the regen queue; re-preemptions count)
    pub seqs_preempted: usize,
    /// decode rounds deferred by control-plane pause windows (sequences
    /// parked in place, nothing dropped)
    pub rounds_paused: usize,
    /// generated tokens preserved across those hand-offs (deposit-time
    /// accounting)
    pub tokens_salvaged: f64,
    /// spare-GPU activations / retirements by the autoscaler
    pub gpus_added: usize,
    pub gpus_removed: usize,
    /// sim times of each scale action (reaction-time measurements)
    pub scaleup_times: Vec<f64>,
    pub scaledown_times: Vec<f64>,
    /// live (non-retired) generation GPUs at completion
    pub gen_gpus_final: usize,
    /// golden-run fingerprint of the whole simulated trajectory
    /// (Some iff `SimCfg::digest`)
    pub digest: Option<RunDigest>,
}

#[derive(Debug, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    /// decode round completes on GPU i
    Round(usize),
    /// optimizer step completes
    TrainDone,
}

pub struct Simulator {
    cfg: SimCfg,
    rng: Rng,
    /// per-GPU slot table (grows when the autoscaler adds spares)
    slots: Vec<Vec<Option<Seq>>>,
    /// retired spare GPUs (never refilled, rounds void)
    retired: Vec<bool>,
    /// a Round event is in the heap for this GPU (guards double
    /// scheduling across retire/reactivate cycles)
    scheduled: Vec<bool>,
    /// regeneration queue: migrated in-flight sequences awaiting a slot
    /// (the rollout-queue backlog signal)
    regen: VecDeque<Seq>,
    queue: VecDeque<Seq>,
    version: u64,
    /// conventional: sequences left to start this RL step
    quota: usize,
    heap: BinaryHeap<Reverse<(u64, Event)>>, // time in nano-flashes
    t: f64,
    steps_done: usize,
    samples: usize,
    trainer_busy: bool,
    scaler: Option<AutoScaler>,
    next_autoscale_t: f64,
    result: SimResult,
    lag_sum_by_bucket: Vec<f64>,
    lag_n_by_bucket: Vec<f64>,
    next_uid: u64,
    /// hash-only digest log (Some iff `SimCfg::digest`)
    log: Option<EventLog>,
}

const BUCKETS: usize = 16;

fn key(t: f64, e: Event) -> Reverse<(u64, Event)> {
    Reverse(((t * 1e6) as u64, e))
}

impl Simulator {
    pub fn new(cfg: SimCfg) -> Self {
        assert!(
            !cfg.migrate || matches!(cfg.mode, SimMode::Pipeline),
            "partial-rollout migration requires SimMode::Pipeline"
        );
        assert!(cfg.kv_block_size > 0, "kv_block_size must be >= 1");
        assert!(
            cfg.kv_blocks_per_gpu.is_none()
                || (cfg.migrate && matches!(cfg.mode, SimMode::Pipeline)),
            "the KV memory-pressure model requires SimMode::Pipeline with \
             migrate: preempted sequences park their prefixes in the regen \
             queue"
        );
        if let Some(budget) = cfg.kv_blocks_per_gpu {
            assert!(
                budget >= cfg.l_max.div_ceil(cfg.kv_block_size),
                "kv_blocks_per_gpu must cover at least one max-length \
                 sequence ({} blocks), got {budget}",
                cfg.l_max.div_ceil(cfg.kv_block_size)
            );
        }
        let autoscale_on = cfg.autoscale.as_ref().is_some_and(|a| a.cfg.enabled);
        assert!(
            !autoscale_on || cfg.migrate,
            "sim autoscaling requires migrate: retiring a spare hands its \
             sequences back through the regen queue"
        );
        let rng = Rng::with_stream(cfg.seed, 0x51u64);
        let slots: Vec<Vec<Option<Seq>>> = (0..cfg.n_gen_gpus)
            .map(|_| vec![None; cfg.slots_per_gpu])
            .collect();
        let quota = match cfg.mode {
            SimMode::Conventional { g } => cfg.batch_b * g,
            SimMode::Pipeline => usize::MAX,
        };
        // the enabled flag gates the sim exactly like the orchestrator
        // gates the supervisor: a present-but-disabled config must not
        // scale (ablation runs compare against it)
        let scaler = cfg
            .autoscale
            .as_ref()
            .filter(|a| a.cfg.enabled)
            .map(|a| AutoScaler::new(a.cfg.clone()));
        let n = slots.len();
        let digest_on = cfg.digest;
        Simulator {
            cfg,
            rng,
            slots,
            retired: vec![false; n],
            scheduled: vec![false; n],
            regen: VecDeque::new(),
            queue: VecDeque::new(),
            version: 0,
            quota,
            heap: BinaryHeap::new(),
            t: 0.0,
            steps_done: 0,
            samples: 0,
            trainer_busy: false,
            scaler,
            next_autoscale_t: 0.0,
            result: SimResult::default(),
            lag_sum_by_bucket: vec![0.0; BUCKETS],
            lag_n_by_bucket: vec![0.0; BUCKETS],
            next_uid: 0,
            log: if digest_on { Some(EventLog::hash_only()) } else { None },
        }
    }

    fn new_seq(&mut self) -> Seq {
        let len = 1 + self.rng.below(self.cfg.l_max);
        let uid = self.next_uid;
        self.next_uid += 1;
        Seq { uid, remaining: len, versions: Vec::new(), total: len }
    }

    /// KV blocks a resident sequence consumes (its next write included).
    fn seq_blocks(&self, seq: &Seq) -> usize {
        let progress = seq.total - seq.remaining;
        (progress + 1).div_ceil(self.cfg.kv_block_size)
    }

    /// Current KV block demand of a GPU's resident sequences.
    fn gpu_kv_demand(&self, gpu: usize) -> usize {
        self.slots[gpu].iter().flatten().map(|s| self.seq_blocks(s)).sum()
    }

    fn refill(&mut self, gpu: usize) {
        if self.retired[gpu] {
            return;
        }
        // admission respects the GPU's KV budget headroom (block-gated
        // admission, exactly like the engine's paged allocator)
        let budget = self.cfg.kv_blocks_per_gpu;
        let mut demand = match budget {
            Some(_) => self.gpu_kv_demand(gpu),
            None => 0,
        };
        for s in 0..self.cfg.slots_per_gpu {
            if self.slots[gpu][s].is_some() {
                continue;
            }
            // migrated prefixes re-enter ahead of fresh prompts (no
            // quota charge: they were already admitted once)
            if let Some(head) = self.regen.front() {
                let need = self.seq_blocks(head);
                if budget.is_none_or(|b| demand + need <= b) {
                    demand += need;
                    let seq = self.regen.pop_front().expect("peeked above");
                    self.slots[gpu][s] = Some(seq);
                    continue;
                }
                // the queue head's prefix does not fit the headroom:
                // hold it (FIFO) — a fresh prompt may still fit below
            }
            if self.quota > 0 {
                if budget.is_some_and(|b| demand + 1 > b) {
                    break; // no headroom left for even a fresh prompt
                }
                demand += 1;
                let seq = self.new_seq();
                if self.quota != usize::MAX {
                    self.quota -= 1;
                }
                self.slots[gpu][s] = Some(seq);
            }
        }
    }

    /// Memory-pressure eviction: while a GPU's resident demand exceeds
    /// its KV budget, park the *youngest* (least-progressed) sequence
    /// into the regen queue — the engine's scheduler-driven preemption
    /// (`[kv] preempt_policy = "youngest"`) on sim time. The last
    /// resident is never parked (it must be able to finish; the budget
    /// floor asserted at construction guarantees it can).
    fn enforce_kv_budget(&mut self, gpu: usize) {
        let Some(budget) = self.cfg.kv_blocks_per_gpu else { return };
        while self.gpu_kv_demand(gpu) > budget {
            if self.slots[gpu].iter().flatten().count() <= 1 {
                return;
            }
            let victim = self.slots[gpu]
                .iter()
                .enumerate()
                .filter_map(|(i, s)| s.as_ref().map(|s| (s.total - s.remaining, i)))
                .min();
            let Some((_, vi)) = victim else { return };
            let seq = self.slots[gpu][vi].take().expect("victim resident");
            self.result.seqs_preempted += 1;
            self.result.tokens_salvaged += (seq.total - seq.remaining) as f64;
            self.regen.push_back(seq);
        }
    }

    /// Push a Round event for `gpu` unless one is already pending.
    fn schedule_round(&mut self, gpu: usize, pause: f64) {
        if self.scheduled[gpu] {
            return;
        }
        let h = self.active(gpu);
        if h == 0 {
            return;
        }
        let dt = h as f64 / self.cfg.accel.u(h) + pause;
        self.heap.push(key(self.t + dt, Event::Round(gpu)));
        self.scheduled[gpu] = true;
    }

    fn active(&self, gpu: usize) -> usize {
        self.slots[gpu].iter().filter(|s| s.is_some()).count()
    }

    /// End of the outage window covering `(gpu, now)`, if any. The 2e-6
    /// tolerance absorbs the micro-flash truncation in [`key`] so a round
    /// rescheduled *at* the window end counts as recovered.
    fn down_until(&self, gpu: usize) -> Option<f64> {
        self.cfg
            .failures
            .iter()
            .filter(|f| f.gpu == gpu && f.at <= self.t && self.t + 2e-6 < f.at + f.down_for)
            .map(|f| f.at + f.down_for)
            .fold(None, |acc: Option<f64>, end| {
                Some(acc.map_or(end, |a| a.max(end)))
            })
    }

    /// End of the control-plane pause window covering `now`, if any.
    /// Pauses are run-wide (every generation GPU parks), with the same
    /// micro-flash tolerance as [`Simulator::down_until`].
    fn paused_until(&self) -> Option<f64> {
        self.cfg
            .pauses
            .iter()
            .filter(|p| p.at <= self.t && self.t + 2e-6 < p.at + p.hold_for)
            .map(|p| p.at + p.hold_for)
            .fold(None, |acc: Option<f64>, end| {
                Some(acc.map_or(end, |a| a.max(end)))
            })
    }

    pub fn run(mut self) -> SimResult {
        // prime
        for g in 0..self.cfg.n_gen_gpus {
            self.refill(g);
            self.schedule_round(g, 0.0);
        }
        let mut gen_done_tokens = 0f64;

        while self.steps_done < self.cfg.rl_steps {
            let Some(Reverse((tk, ev))) = self.heap.pop() else {
                break; // deadlock guard (should not happen)
            };
            self.t = tk as f64 / 1e6;
            // supervisor-poll analogue: evaluate the autoscaler on sim
            // time, decoupled from the (possibly slow) trainer cadence
            self.maybe_autoscale();
            match ev {
                Event::Round(g) => {
                    self.scheduled[g] = false;
                    if self.retired[g] {
                        // a round scheduled before retirement is void
                        // (retire_spare already migrated the sequences)
                        continue;
                    }
                    // control-plane pause: park in place — the resident
                    // sequences keep their slots, prefixes and version
                    // runs, and the round simply re-arms at the window
                    // end. Unlike an outage, nothing is dropped or
                    // migrated; the trainer keeps draining whatever
                    // finished before the pause.
                    if let Some(end) = self.paused_until() {
                        self.result.rounds_paused += 1;
                        if g == 0 {
                            self.result.gpu0_active.push(self.t, self.t, self.active(0) as f64);
                        }
                        self.heap.push(key(end, Event::Round(g)));
                        self.scheduled[g] = true;
                        self.maybe_start_training();
                        continue;
                    }
                    // injected outage: drop live sequences, go dark until
                    // the window ends, then resume (pipeline refills).
                    // With migration the dropped sequences keep their
                    // prefixes and re-enter via the regen queue.
                    if let Some(end) = self.down_until(g) {
                        let dropped: Vec<Seq> =
                            self.slots[g].iter_mut().filter_map(|s| s.take()).collect();
                        if self.cfg.migrate {
                            self.result.seqs_migrated += dropped.len();
                            for s in dropped {
                                self.result.tokens_salvaged +=
                                    (s.total - s.remaining) as f64;
                                self.regen.push_back(s);
                            }
                        } else {
                            let n_dropped = dropped.len();
                            self.result.seqs_lost += n_dropped;
                            if matches!(self.cfg.mode, SimMode::Conventional { .. })
                                && n_dropped > 0
                            {
                                // conventional churn: refund the phase
                                // quota so the generate phase still
                                // drains — the work regenerates from
                                // scratch (the barrier cannot salvage
                                // partial sequences) on whichever GPU
                                // has room, starting now
                                if self.quota != usize::MAX {
                                    self.quota += n_dropped;
                                }
                                for gpu in 0..self.cfg.n_gen_gpus {
                                    if gpu != g {
                                        self.refill(gpu);
                                        self.schedule_round(gpu, 0.0);
                                    }
                                }
                            }
                        }
                        if g == 0 {
                            self.result.gpu0_active.push(self.t, self.t, 0.0);
                        }
                        self.heap.push(key(end, Event::Round(g)));
                        self.scheduled[g] = true;
                        self.maybe_start_training();
                        continue;
                    }
                    let mut finished = Vec::new();
                    // digest: the round's tokens in canonical sequence-id
                    // order (slot placement must not affect the hash)
                    let mut round_log: Vec<(u64, u32)> = Vec::new();
                    for slot in self.slots[g].iter_mut() {
                        if let Some(seq) = slot {
                            // one token generated under the current version
                            match seq.versions.last_mut() {
                                Some((v, c)) if *v == self.version => *c += 1,
                                _ => seq.versions.push((self.version, 1)),
                            }
                            seq.remaining -= 1;
                            gen_done_tokens += 1.0;
                            if self.log.is_some() {
                                round_log
                                    .push((seq.uid, (seq.total - seq.remaining - 1) as u32));
                            }
                            if seq.remaining == 0 {
                                finished.push(slot.take().unwrap());
                            }
                        }
                    }
                    if let Some(log) = &mut self.log {
                        round_log.sort_unstable();
                        let version = self.version;
                        for (uid, index) in round_log {
                            log.record(DigestEvent::Token { seq: uid, index, tok: 0, version });
                        }
                        let mut done: Vec<(u64, u64)> =
                            finished.iter().map(|s| (s.uid, s.total as u64)).collect();
                        done.sort_unstable();
                        for (uid, total) in done {
                            log.record(DigestEvent::GroupComplete { group: uid, tokens: total });
                        }
                    }
                    self.queue.extend(finished);
                    // memory pressure first (this round's tokens may have
                    // outgrown the KV budget), then refill into whatever
                    // slots and block headroom remain
                    self.enforce_kv_budget(g);
                    // in-flight refill (pipeline) / quota refill (conv)
                    self.refill(g);
                    if g == 0 {
                        self.result.gpu0_active.push(self.t, self.t, self.active(0) as f64);
                    }
                    self.schedule_round(g, self.cfg.weight_update_pause); // pause amortized
                    self.maybe_start_training();
                }
                Event::TrainDone => {
                    self.trainer_busy = false;
                    self.steps_done += 1;
                    self.version += 1;
                    self.samples += self.cfg.batch_b;
                    if let Some(log) = &mut self.log {
                        log.record(DigestEvent::TrainerStep {
                            step: self.steps_done as u64,
                            param_hash: self.samples as u64,
                        });
                        log.record(DigestEvent::WeightPublish { version: self.version });
                    }
                    self.result.samples_vs_time.push(self.t, self.t, self.samples as f64);
                    if let SimMode::Conventional { g } = self.cfg.mode {
                        // RL step boundary: reopen generation quota
                        let steps_into = self.steps_done % g;
                        if steps_into == 0 {
                            self.quota = self.cfg.batch_b * g;
                            for gpu in 0..self.cfg.n_gen_gpus {
                                self.refill(gpu);
                                self.schedule_round(gpu, 0.0);
                            }
                        }
                    }
                    self.maybe_start_training();
                }
            }
        }

        self.result.digest = self.log.as_ref().map(|l| l.digest());
        self.result.tokens = gen_done_tokens;
        self.result.t_end = self.t;
        self.result.throughput = gen_done_tokens / self.t.max(1e-9);
        self.result.gen_gpus_final = self.retired.iter().filter(|r| !**r).count();
        self.result.lag_by_relpos = self
            .lag_sum_by_bucket
            .iter()
            .zip(&self.lag_n_by_bucket)
            .map(|(s, n)| if *n > 0.0 { s / n } else { 0.0 })
            .collect();
        self.result
    }

    /// Evaluate the autoscaler at its configured sim-time cadence: the
    /// regen queue is the rollout-queue backlog (scale-up pressure), the
    /// trainer inbox is the supply buffer (scale-down pressure). Uses the
    /// same [`AutoScaler`] the supervisor runs, so hysteresis behavior is
    /// pinned by one implementation.
    fn maybe_autoscale(&mut self) {
        let Some(auto) = &self.cfg.autoscale else { return };
        if self.scaler.is_none() || self.t < self.next_autoscale_t {
            return;
        }
        self.next_autoscale_t = self.t + auto.eval_every_flashes.max(1e-6);
        let live = self.retired.iter().filter(|r| !**r).count();
        let cap = auto.supply_capacity.max(1);
        let sig = ScaleSignals {
            backlog: self.regen.len(),
            supply_depth: self.queue.len().min(cap),
            supply_capacity: cap,
            token_lag: self.result.mean_lag.last().map(|p| p.value).unwrap_or(0.0),
            // the simulator models no IS weighting: report fully on-policy
            // so an ess_floor config can't pin its guard shut
            ess: 1.0,
            batch_fill: 1.0,
            pool: live,
        };
        let max_extra = auto.max_extra_gpus;
        let decision = self.scaler.as_mut().expect("checked above").decide(&sig);
        match decision {
            ScaleDecision::Up => self.activate_spare(max_extra),
            ScaleDecision::Down => self.retire_spare(),
            ScaleDecision::Hold => {}
        }
    }

    /// Bring up a spare generation GPU: reactivate a retired one, or add
    /// a new row up to `n_gen_gpus + max_extra`. No-op at the ceiling.
    fn activate_spare(&mut self, max_extra: usize) {
        let g = if let Some(g) = self.retired.iter().position(|r| *r) {
            self.retired[g] = false;
            g
        } else if self.slots.len() < self.cfg.n_gen_gpus + max_extra {
            self.slots.push(vec![None; self.cfg.slots_per_gpu]);
            self.retired.push(false);
            self.scheduled.push(false);
            self.slots.len() - 1
        } else {
            return;
        };
        // if a pre-retirement Round for this GPU is still in the heap, let
        // it serve as the activation tick: it will find the slots empty
        // (retire_spare migrated them out), refill, and reschedule.
        // Refilling *now* would let that stale deadline — computed from
        // the old occupancy and start time — credit a full decode round
        // to sequences that were not resident for it.
        if !self.scheduled[g] {
            self.refill(g);
            self.schedule_round(g, 0.0);
        }
        self.result.gpus_added += 1;
        self.result.scaleup_times.push(self.t);
    }

    /// Retire the highest live spare (indices beyond the designed tier —
    /// the configured topology is the floor). Its in-flight sequences
    /// migrate back through the regen queue, prefixes intact.
    fn retire_spare(&mut self) {
        let Some(g) = (self.cfg.n_gen_gpus..self.slots.len()).rev().find(|&g| !self.retired[g])
        else {
            return;
        };
        self.retired[g] = true;
        let moved: Vec<Seq> = self.slots[g].iter_mut().filter_map(|s| s.take()).collect();
        self.result.seqs_migrated += moved.len();
        for s in moved {
            self.result.tokens_salvaged += (s.total - s.remaining) as f64;
            self.regen.push_back(s);
        }
        self.result.gpus_removed += 1;
        self.result.scaledown_times.push(self.t);
    }

    fn maybe_start_training(&mut self) {
        if self.trainer_busy || self.queue.len() < self.cfg.batch_b {
            return;
        }
        if let SimMode::Conventional { .. } = self.cfg.mode {
            // Alg. 1: wait for the full generation phase to drain
            let any_active = (0..self.cfg.n_gen_gpus).any(|g| self.active(g) > 0);
            if self.quota > 0 || any_active {
                return;
            }
        }
        // form a batch and account lag
        let mut tokens = 0usize;
        let mut max_lag = 0u64;
        let mut lag_sum = 0f64;
        let mut lag_n = 0f64;
        let train_version = self.version; // steps applied so far
        for _ in 0..self.cfg.batch_b {
            let seq = self.queue.pop_front().unwrap();
            tokens += seq.total;
            let mut idx = 0usize;
            for (v, c) in &seq.versions {
                let lag = train_version.saturating_sub(*v);
                max_lag = max_lag.max(lag);
                lag_sum += (lag * *c as u64) as f64;
                lag_n += *c as f64;
                for k in 0..*c {
                    let rel = (idx + k) * BUCKETS / seq.total.max(1);
                    self.lag_sum_by_bucket[rel.min(BUCKETS - 1)] += lag as f64;
                    self.lag_n_by_bucket[rel.min(BUCKETS - 1)] += 1.0;
                }
                idx += *c;
            }
        }
        let step = self.steps_done as f64 + 1.0;
        self.result.max_lag.push(self.t, step, max_lag as f64);
        self.result
            .mean_lag
            .push(self.t, step, if lag_n > 0.0 { lag_sum / lag_n } else { 0.0 });
        let dt = tokens as f64 * self.cfg.tau / self.cfg.n_train_gpus as f64;
        self.trainer_busy = true;
        self.heap.push(key(self.t + dt, Event::TrainDone));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_pipe() -> SimCfg {
        let mut c = SimCfg::pipeline(16, 8, 32, 64, 128);
        c.rl_steps = 30;
        c
    }

    #[test]
    fn pipeline_completes_and_reports() {
        let r = Simulator::new(small_pipe()).run();
        assert_eq!(r.samples_vs_time.points.len(), 30);
        assert!(r.throughput > 0.0);
        assert!(r.tokens > 0.0);
    }

    #[test]
    fn pipeline_keeps_generation_batch_constant() {
        let r = Simulator::new(small_pipe()).run();
        // after warmup, gpu0 active slots stay at H (in-flight refills)
        let vals = r.gpu0_active.values();
        let tail = &vals[vals.len() / 2..];
        assert!(tail.iter().all(|&v| v == 32.0), "constant batch: {tail:?}");
    }

    #[test]
    fn conventional_batch_drains() {
        let mut c = SimCfg::conventional(16, 4, 32, 64, 128);
        c.rl_steps = 8;
        let r = Simulator::new(c).run();
        // active slots must visit low values during the drain (Fig 2b)
        let vals = r.gpu0_active.values();
        assert!(vals.iter().any(|&v| v <= 4.0), "drain tail must appear");
        assert!(vals.iter().any(|&v| v == 32.0), "starts full");
    }

    #[test]
    fn pipeline_lag_structure_earlier_tokens_lag_more() {
        let mut c = small_pipe();
        c.rl_steps = 60;
        let r = Simulator::new(c).run();
        // Fig 3a: earlier relative positions have strictly higher mean lag
        let first = r.lag_by_relpos[0];
        let last = r.lag_by_relpos[BUCKETS - 1];
        assert!(
            first > last,
            "early tokens lag more: first {first} last {last} ({:?})",
            r.lag_by_relpos
        );
    }

    #[test]
    fn conventional_sequences_are_single_version() {
        let mut c = SimCfg::conventional(8, 2, 16, 32, 64);
        c.rl_steps = 6;
        let r = Simulator::new(c).run();
        // lag profile flat across positions within an RL step
        let prof = &r.lag_by_relpos;
        let spread = prof.iter().cloned().fold(f64::MIN, f64::max)
            - prof.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread < 0.75, "conventional lag is flat per batch: {prof:?}");
    }

    #[test]
    fn conventional_lag_bounded_by_g() {
        let g = 4;
        let mut c = SimCfg::conventional(8, g, 16, 32, 64);
        c.rl_steps = 12;
        let r = Simulator::new(c).run();
        for p in &r.max_lag.points {
            assert!(p.value <= g as f64, "lag {} > g {}", p.value, g);
        }
    }

    #[test]
    fn pipeline_beats_conventional_wallclock_at_scale() {
        // the headline: same B, same N, PipelineRL finishes its steps in
        // less wall-clock (flash) time than Conventional G=32.
        let n = 32;
        let b = 64;
        let l = 256;
        let mut pipe = SimCfg::pipeline(n, 12, 96, b, l);
        pipe.rl_steps = 32;
        let mut conv = SimCfg::conventional(n, 32, 64, b, l);
        conv.rl_steps = 32;
        let rp = Simulator::new(pipe).run();
        let rc = Simulator::new(conv).run();
        assert!(
            rp.t_end < rc.t_end,
            "pipeline {:.0} flashes vs conventional {:.0}",
            rp.t_end,
            rc.t_end
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Simulator::new(small_pipe()).run();
        let b = Simulator::new(small_pipe()).run();
        assert_eq!(a.t_end, b.t_end);
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn digest_fingerprints_the_whole_trajectory() {
        let mk = |seed: u64| {
            let mut c = small_pipe();
            c.seed = seed;
            c.digest = true;
            Simulator::new(c).run()
        };
        let a = mk(1);
        let b = mk(1);
        let d = a.digest.expect("digest requested");
        assert_eq!(Some(d), b.digest, "same seed replays to the same digest");
        assert!(d.events > 0);
        // a different seed must be distinguishable — the digest is a
        // fingerprint, not a parity bit
        let c = mk(2);
        assert_ne!(a.digest, c.digest);
        // churn + migration replays deterministically too, and its
        // perturbed timing is visible in the fingerprint
        let healthy_end = a.t_end;
        let churned = || {
            let mut c = small_pipe();
            c.seed = 1;
            c.migrate = true;
            let mut c = c.with_churn(5, 4, healthy_end, healthy_end / 8.0);
            c.digest = true;
            Simulator::new(c).run()
        };
        let e = churned();
        let f = churned();
        assert_eq!(e.digest, f.digest, "churn replays deterministically");
        assert!(e.seqs_migrated > 0, "the outages must have hit live work");
        assert_ne!(a.digest, e.digest, "outages visibly change sim timing");
        // digest off: no fingerprint
        let plain = Simulator::new(small_pipe()).run();
        assert!(plain.digest.is_none());
    }

    #[test]
    fn pause_windows_park_in_place_and_lose_nothing() {
        // a control-plane pause defers decode rounds but drops nothing:
        // the run completes every optimizer step, seqs_lost stays zero,
        // generated work is identical to the healthy run, and the paused
        // trajectory replays deterministically
        let healthy = Simulator::new(small_pipe()).run();
        let mk = || {
            let mut c = small_pipe();
            c.pauses = vec![
                PauseWindow { at: healthy.t_end / 4.0, hold_for: healthy.t_end / 8.0 },
                PauseWindow { at: healthy.t_end / 2.0, hold_for: healthy.t_end / 8.0 },
            ];
            c
        };
        let r = Simulator::new(mk()).run();
        assert!(r.rounds_paused > 0, "the windows must have deferred rounds");
        assert_eq!(r.seqs_lost, 0, "a pause parks in place, it never drops");
        assert_eq!(r.seqs_migrated, 0, "nothing re-enters the regen queue");
        assert_eq!(r.samples_vs_time.points.len(), 30, "every step completes");
        assert!(
            r.t_end >= healthy.t_end,
            "pausing cannot make the run faster: {} vs {}",
            r.t_end,
            healthy.t_end
        );
        let again = Simulator::new(mk()).run();
        assert_eq!(r.t_end, again.t_end);
        assert_eq!(r.rounds_paused, again.rounds_paused);
        assert_eq!(r.tokens, again.tokens);
    }

    #[test]
    fn healthy_cluster_loses_nothing() {
        let r = Simulator::new(small_pipe()).run();
        assert_eq!(r.seqs_lost, 0);
    }

    #[test]
    fn churn_drops_sequences_but_pipeline_completes() {
        let healthy = Simulator::new(small_pipe()).run();
        // knock GPUs out across the healthy run's whole horizon
        let cfg = small_pipe().with_churn(11, 6, healthy.t_end, healthy.t_end / 10.0);
        let r = Simulator::new(cfg).run();
        assert_eq!(
            r.samples_vs_time.points.len(),
            30,
            "pipeline refills around outages and still finishes every step"
        );
        assert!(r.seqs_lost > 0, "outages must have dropped live sequences");
        assert!(
            r.t_end >= healthy.t_end,
            "churn cannot make the run faster: {} vs {}",
            r.t_end,
            healthy.t_end
        );
    }

    #[test]
    fn migration_salvages_outage_work() {
        let healthy = Simulator::new(small_pipe()).run();
        let mut cfg = small_pipe().with_churn(11, 6, healthy.t_end, healthy.t_end / 10.0);
        cfg.migrate = true;
        let r = Simulator::new(cfg).run();
        assert_eq!(r.seqs_lost, 0, "migration leaves no sequence lost");
        assert!(r.seqs_migrated > 0, "outages must have migrated sequences");
        assert!(r.tokens_salvaged > 0.0, "prefixes carried generated tokens");
        assert_eq!(
            r.samples_vs_time.points.len(),
            30,
            "run still completes every optimizer step"
        );
    }

    fn autoscaled_outage_cfg() -> SimCfg {
        let mut c = SimCfg::pipeline(16, 8, 32, 64, 128);
        c.rl_steps = 60;
        c.migrate = true;
        // train-bound cluster: once generation capacity recovers, the
        // trainer inbox saturates and the scale-down pressure is real
        c.tau = 12.0;
        // knock out 6 of the 8 generation GPUs for a long window: their
        // ~192 in-flight sequences flood the regen queue (the sustained
        // rollout-queue backlog) while capacity is down
        c.failures = (0..6)
            .map(|g| GpuFailure { gpu: g, at: 50.0, down_for: 3000.0 })
            .collect();
        c.autoscale = Some(SimAutoScale {
            cfg: AutoScaleCfg {
                enabled: true,
                backlog_per_actor: 1.0,
                supply_high_frac: 0.75,
                up_patience: 2,
                down_patience: 3,
                cooldown: 2,
                max_lag_steps: 0.0,
                ess_floor: 0.0,
                min_batch_fill: 0.0,
                eval_every_ms: 0,
            },
            max_extra_gpus: 4,
            eval_every_flashes: 20.0,
            supply_capacity: 256,
        });
        c
    }

    /// The acceptance scenario in the deterministic simulator: a
    /// sustained rollout-queue backlog (outage-orphaned sequences) grows
    /// the generation pool; once the backlog clears and the victims
    /// recover — generation then overruns the trainer and saturates its
    /// inbox — the spares retire back with hysteresis, and the whole
    /// trajectory replays exactly.
    #[test]
    fn autoscaler_grows_under_backlog_and_shrinks_back() {
        let r = Simulator::new(autoscaled_outage_cfg()).run();
        assert!(r.gpus_added >= 1, "sustained backlog must activate spares");
        assert!(r.gpus_removed >= 1, "cleared backlog must retire spares");
        assert_eq!(r.seqs_lost, 0);
        assert!(r.seqs_migrated > 0);
        assert!(
            r.gen_gpus_final <= 8 + (r.gpus_added - r.gpus_removed),
            "live tier accounts for adds minus removes"
        );
        // no flapping: actions bounded by the spare tier crossed once in
        // each direction (plus bounded re-trips), not proportional to
        // evaluation count
        assert!(
            r.gpus_added + r.gpus_removed <= 12,
            "flapping: {} adds / {} removes",
            r.gpus_added,
            r.gpus_removed
        );
        assert!(
            r.scaleup_times.first() < r.scaledown_times.first(),
            "growth precedes shrink: {:?} vs {:?}",
            r.scaleup_times,
            r.scaledown_times
        );
        assert_eq!(r.samples_vs_time.points.len(), 60, "training completes");
        // deterministic: the exact same trajectory replays
        let again = Simulator::new(autoscaled_outage_cfg()).run();
        assert_eq!(r.t_end, again.t_end);
        assert_eq!(r.gpus_added, again.gpus_added);
        assert_eq!(r.gpus_removed, again.gpus_removed);
        assert_eq!(r.scaleup_times, again.scaleup_times);
        assert_eq!(r.seqs_migrated, again.seqs_migrated);
    }

    #[test]
    fn conventional_churn_refunds_quota_and_completes() {
        // the documented GpuFailure gap, closed: conventional mode now
        // refunds dropped sequences' quota so the generate phase still
        // drains around outages (work restarts from scratch — the phase
        // barrier cannot salvage partial sequences)
        // generation-heavy shape (fast trainer, long sequences) so the
        // seeded outages land in generate phases, where slots are busy
        let base = || {
            let mut c = SimCfg::conventional(8, 2, 16, 32, 64);
            c.rl_steps = 8;
            c.tau = 0.5;
            c
        };
        let mk = || {
            let healthy_end = Simulator::new(base()).run().t_end;
            base().with_churn(17, 6, healthy_end, healthy_end / 8.0)
        };
        let r = Simulator::new(mk()).run();
        assert_eq!(
            r.samples_vs_time.points.len(),
            8,
            "quota refund lets every optimizer step complete despite churn"
        );
        assert!(r.seqs_lost > 0, "outages must have dropped live sequences");
        assert_eq!(r.seqs_migrated, 0, "conventional cannot salvage partial work");
        let again = Simulator::new(mk()).run();
        assert_eq!(r.t_end, again.t_end);
        assert_eq!(r.seqs_lost, again.seqs_lost);
    }

    fn kv_pressure_cfg() -> SimCfg {
        let mut c = SimCfg::pipeline(16, 8, 32, 64, 128);
        c.rl_steps = 30;
        c.migrate = true;
        c.kv_block_size = 16;
        // worst case per GPU is 32 slots × 8 blocks = 256; a 64-block
        // budget is a 4× oversubscription — sustained memory pressure
        c.kv_blocks_per_gpu = Some(64);
        c
    }

    #[test]
    fn kv_pressure_preempts_youngest_and_run_completes() {
        let r = Simulator::new(kv_pressure_cfg()).run();
        assert!(r.seqs_preempted > 0, "the budget must have forced preemptions");
        assert_eq!(r.seqs_lost, 0, "preemption parks, never loses");
        assert_eq!(
            r.samples_vs_time.points.len(),
            30,
            "training completes under sustained memory pressure"
        );
        assert!(r.tokens_salvaged > 0.0, "parked prefixes carried tokens");
        let again = Simulator::new(kv_pressure_cfg()).run();
        assert_eq!(r.t_end, again.t_end);
        assert_eq!(r.seqs_preempted, again.seqs_preempted);
    }

    #[test]
    fn kv_pressure_backlog_activates_spares() {
        // memory pressure, not an outage, is the backlog source: homeless
        // preempted sequences pile into the regen queue and the same
        // autoscaler policy the supervisor runs brings up spare GPUs
        let mk = || {
            let mut c = kv_pressure_cfg();
            c.rl_steps = 40;
            c.tau = 12.0;
            c.autoscale = Some(SimAutoScale {
                cfg: AutoScaleCfg {
                    enabled: true,
                    backlog_per_actor: 1.0,
                    supply_high_frac: 0.75,
                    up_patience: 2,
                    down_patience: 3,
                    cooldown: 2,
                    max_lag_steps: 0.0,
                    ess_floor: 0.0,
                    min_batch_fill: 0.0,
                    eval_every_ms: 0,
                },
                max_extra_gpus: 4,
                eval_every_flashes: 20.0,
                supply_capacity: 256,
            });
            c
        };
        let r = Simulator::new(mk()).run();
        assert!(r.seqs_preempted > 0);
        assert!(
            r.gpus_added >= 1,
            "sustained preemption backlog must activate spares ({} preempted)",
            r.seqs_preempted
        );
        assert_eq!(r.seqs_lost, 0);
        assert_eq!(r.samples_vs_time.points.len(), 40);
        let again = Simulator::new(mk()).run();
        assert_eq!(r.t_end, again.t_end);
        assert_eq!(r.gpus_added, again.gpus_added);
        assert_eq!(r.seqs_preempted, again.seqs_preempted);
    }

    #[test]
    #[should_panic(expected = "requires SimMode::Pipeline")]
    fn kv_pressure_requires_pipeline_and_migrate() {
        let mut c = SimCfg::pipeline(8, 4, 16, 32, 64);
        c.kv_blocks_per_gpu = Some(16); // migrate off
        let _ = Simulator::new(c);
    }

    #[test]
    #[should_panic(expected = "requires migrate")]
    fn autoscale_without_migrate_is_refused() {
        let mut c = small_pipe();
        c.autoscale = Some(SimAutoScale {
            cfg: AutoScaleCfg { enabled: true, ..AutoScaleCfg::default() },
            max_extra_gpus: 1,
            eval_every_flashes: 10.0,
            supply_capacity: 64,
        });
        let _ = Simulator::new(c);
    }

    #[test]
    fn disabled_autoscale_config_never_scales() {
        // present-but-disabled autoscale: the ablation baseline must not
        // scale (and, being inert, needs no migrate either)
        let mut c = autoscaled_outage_cfg();
        c.autoscale.as_mut().unwrap().cfg.enabled = false;
        let r = Simulator::new(c).run();
        assert_eq!(r.gpus_added + r.gpus_removed, 0);
        assert_eq!(r.gen_gpus_final, 8);
        assert!(r.seqs_migrated > 0, "migration itself still works");
    }

    #[test]
    fn churn_is_seed_deterministic() {
        let mk = || {
            let healthy_end = 5_000.0;
            let cfg = small_pipe().with_churn(21, 4, healthy_end, 300.0);
            Simulator::new(cfg).run()
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.seqs_lost, b.seqs_lost);
        assert_eq!(a.t_end, b.t_end);
        assert_eq!(a.tokens, b.tokens);
    }
}
