//! Learning-speed simulation — the paper's supplementary result:
//! *"for the same maximum lag g_max PipelineRL can learn 1.5x faster
//! than Conventional RL"* (§4).
//!
//! §3 decomposes learning speed as ΔR/Δt = (ΔR/ΔS) · (ΔS/Δt).
//! Throughput ΔS/Δt comes from the Appendix-A model; learning
//! effectiveness ΔR/ΔS cannot be derived analytically (the paper makes
//! the same caveat), so we model it at the *token* level (the unit the
//! paper's lag analysis, Fig 3a, is stated in): each trained token's
//! contribution is discounted by its own lag,
//!
//!   dR/dS = R'(S) · E_tokens[ 1 / (1 + α · lag_token) ].
//!
//! The two methods then differ in exactly the two places the paper
//! identifies: their throughput (same-lag r_pipeline > r_conv, Fig 9)
//! and their token-lag *distribution* — PipelineRL batches mix lags
//! uniformly over 0..g_max (the Fig 3a ramp), Conventional's batch j is
//! uniformly at lag j. Averaged over an RL step both have the same mean
//! effectiveness (the expectation of the same discount over the same
//! support), so the same-g_max speedup isolates the throughput ratio —
//! which is how the supplementary "~1.5× at equal g_max" arises.

use super::search::search_pipeline_configs;
use super::throughput::{conventional, Workload};

#[derive(Debug, Clone)]
pub struct LearningCurve {
    /// (time in flashes, reward) samples
    pub points: Vec<(f64, f64)>,
}

impl LearningCurve {
    pub fn time_to(&self, reward: f64) -> Option<f64> {
        self.points.iter().find(|(_, r)| *r >= reward).map(|(t, _)| *t)
    }

    pub fn final_reward(&self) -> f64 {
        self.points.last().map(|(_, r)| *r).unwrap_or(0.0)
    }
}

#[derive(Debug, Clone)]
pub struct LearnCfg {
    /// asymptotic reward of the base curve
    pub r_max: f64,
    /// samples to reach 63% of r_max at zero lag
    pub s0: f64,
    /// lag discount strength α (per optimizer step of mean lag)
    pub alpha: f64,
    /// optimizer steps to simulate
    pub steps: usize,
}

impl Default for LearnCfg {
    fn default() -> Self {
        LearnCfg { r_max: 0.8, s0: 50_000.0, alpha: 0.02, steps: 1000 }
    }
}

/// Simulate R(t) for a method with sample throughput `r` (tokens/flash)
/// and a per-step token-lag *effectiveness* `eff_of_step(step)` in (0,1].
/// Tokens→samples via the workload's mean length.
pub fn simulate(
    w: &Workload,
    lc: &LearnCfg,
    tokens_per_flash: f64,
    eff_of_step: impl Fn(usize) -> f64,
) -> LearningCurve {
    let samples_per_flash = tokens_per_flash / w.l_bar();
    let dt_per_step = w.b as f64 / samples_per_flash; // flashes per optimizer step
    let mut s = 0.0f64;
    let mut r = 0.0f64;
    let mut t = 0.0f64;
    let mut points = vec![(0.0, 0.0)];
    for step in 0..lc.steps {
        let eff = eff_of_step(step);
        // base curve derivative at the current *effective* progress
        let dr_ds = (lc.r_max - r) / lc.s0;
        r += dr_ds * eff * w.b as f64;
        s += w.b as f64;
        t += dt_per_step;
        points.push((t, r.min(lc.r_max)));
    }
    let _ = s;
    LearningCurve { points }
}

/// Same-g_max comparison (the supplementary figure): best pipeline
/// configuration with lag ≤ g_max vs conventional G = g_max.
pub fn same_lag_comparison(
    w: &Workload,
    lc: &LearnCfg,
    g_max: usize,
) -> (LearningCurve, LearningCurve, f64) {
    let grid: Vec<usize> = (4..=512).step_by(4).collect();
    let pipe = search_pipeline_configs(w, &[g_max], &grid)[0]
        .1
        .expect("pipeline config for lag budget");
    let conv = conventional(w, g_max);

    // PipelineRL: every batch mixes token lags ~ Uniform(0..g_max)
    // (the Fig 3a ramp): eff = E[1/(1 + α·l)]
    let a = lc.alpha;
    let gp = pipe.lag_steps as f64;
    let pipe_eff = if gp > 0.0 { ((1.0 + a * gp).ln()) / (a * gp) } else { 1.0 };
    let pipe_curve = simulate(w, lc, pipe.r, move |_| pipe_eff);
    // Conventional: batch j of each RL step is uniformly at lag j
    let g = conv.g;
    let conv_curve = simulate(w, lc, conv.r, move |step| {
        1.0 / (1.0 + a * (step % g) as f64)
    });

    // speedup = ratio of times to the half-max reward
    let target = lc.r_max * 0.5;
    let speedup = match (conv_curve.time_to(target), pipe_curve.time_to(target)) {
        (Some(tc), Some(tp)) => tc / tp,
        _ => f64::NAN,
    };
    (pipe_curve, conv_curve, speedup)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_lag_follows_base_curve() {
        let w = Workload::paper_a4();
        let lc = LearnCfg::default();
        let c = simulate(&w, &lc, 10.0, |_| 1.0);
        // saturating growth towards r_max
        assert!(c.final_reward() > 0.9 * lc.r_max);
        let mid = c.points[c.points.len() / 2].1;
        assert!(mid > 0.5 * c.final_reward());
    }

    #[test]
    fn lag_slows_learning_per_sample() {
        let w = Workload::paper_a4();
        let lc = LearnCfg::default();
        let fast = simulate(&w, &lc, 10.0, |_| 1.0);
        let slow = simulate(&w, &lc, 10.0, |_| 0.5);
        assert!(slow.final_reward() < fast.final_reward());
    }

    #[test]
    fn supplementary_speedup_at_least_1_4x() {
        // the paper's supplementary simulation: ~1.5x at matched g_max
        let w = Workload::paper_a4();
        let lc = LearnCfg::default();
        let (_p, _c, speedup) = same_lag_comparison(&w, &lc, 133);
        assert!(
            speedup > 1.35 && speedup < 2.2,
            "speedup {speedup} (paper: ~1.5x)"
        );
    }

    #[test]
    fn speedup_monotonicity_sanity() {
        let w = Workload::paper_a4();
        let lc = LearnCfg::default();
        let (_, _, s64) = same_lag_comparison(&w, &lc, 64);
        let (_, _, s133) = same_lag_comparison(&w, &lc, 133);
        assert!(s64 > 1.0 && s133 > 1.0, "pipeline wins at both lags");
    }
}
