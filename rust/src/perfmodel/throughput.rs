//! Throughput equations — paper Appendix A.2 (conventional) and A.3
//! (pipeline).
//!
//! Conventional RL (Eqs. 10–15): each RL step generates S = B·G
//! sequences on all N GPUs, *draining* the batch as short sequences
//! finish (h(l) shrinks with decode step l), then trains on the K tokens.
//!
//! PipelineRL (Eqs. 16–18): I GPUs generate at a *constant* batch H
//! (in-flight refills), N−I GPUs train concurrently; system throughput is
//! the min of the two stages. Max lag g_max = ⌈H·I·L / (L̄·B)⌉.

use super::utilization::AccelModel;

/// Workload + hardware assumptions shared by both formulas.
#[derive(Debug, Clone)]
pub struct Workload {
    /// total GPUs
    pub n: usize,
    /// optimizer batch (sequences per optimizer step)
    pub b: usize,
    /// maximum sequence length; lengths ~ Uniform{1..L} (paper A.4)
    pub l_max: usize,
    /// amortized training flashes per token (fwd+bwd+opt at train
    /// utilization; calibrated to the A.4 case study: τ = 4.92)
    pub tau: f64,
    pub accel: AccelModel,
}

impl Workload {
    pub fn paper_a4() -> Self {
        Workload {
            n: 128,
            b: 128,
            l_max: 2048,
            tau: 4.92,
            accel: AccelModel::h100(),
        }
    }

    /// average sequence length under the uniform assumption
    pub fn l_bar(&self) -> f64 {
        (self.l_max as f64 + 1.0) / 2.0
    }
}

#[derive(Debug, Clone)]
pub struct ConvPoint {
    pub g: usize,
    /// sequences per RL step S = B·G
    pub s: usize,
    pub r_gen: f64,
    pub r_train: f64,
    /// combined tokens/flash (Eq. 13)
    pub r: f64,
    /// max token lag in samples (paper: S − 1)
    pub lag_samples: usize,
    /// max token lag in optimizer steps (≈ G)
    pub lag_steps: f64,
}

/// Conventional RL throughput for G optimizer steps per RL step.
pub fn conventional(w: &Workload, g: usize) -> ConvPoint {
    let s = w.b * g;
    let l = w.l_max;
    let k = s as f64 * w.l_bar(); // tokens per RL step

    // h(l): sequences still alive after l decode steps; uniform lengths
    // 1..L  =>  h(l) = S * (L - l) / L
    // t_gen = Σ_l (h(l)/N) / U(h(l)/N)   [flashes]
    let mut t_gen = 0.0;
    for step in 0..l {
        let alive = (s as f64 * (l - step) as f64 / l as f64).ceil();
        if alive < 1.0 {
            break;
        }
        let per_gpu = alive / w.n as f64;
        // average over GPUs holding ceil/floor counts: use fractional h
        // via interpolation of U at the two nearest integers
        let u = u_frac(&w.accel, per_gpu);
        if u <= 0.0 {
            continue;
        }
        t_gen += per_gpu / u;
    }
    let r_gen = k / t_gen;
    let r_train = w.n as f64 / w.tau;
    let r = 1.0 / (1.0 / r_gen + 1.0 / r_train);
    ConvPoint {
        g,
        s,
        r_gen,
        r_train,
        r,
        lag_samples: s.saturating_sub(1),
        lag_steps: g as f64,
    }
}

#[derive(Debug, Clone, Copy)]
pub struct PipePoint {
    /// inference GPUs
    pub i: usize,
    /// generation batch per inference GPU
    pub h: usize,
    pub r_gen: f64,
    pub r_train: f64,
    pub r: f64,
    /// g_max = ceil(H I L / (L̄ B)) (A.3)
    pub lag_steps: usize,
    pub lag_samples: usize,
}

/// PipelineRL throughput for (I inference GPUs, batch H each).
pub fn pipeline(w: &Workload, i: usize, h: usize) -> PipePoint {
    let r_gen = w.accel.u(h) * i as f64; // Eq. 17
    let r_train = (w.n - i) as f64 / w.tau; // Eq. 18
    let r = r_gen.min(r_train);
    let lag = ((h * i) as f64 * w.l_max as f64 / (w.l_bar() * w.b as f64)).ceil() as usize;
    PipePoint {
        i,
        h,
        r_gen,
        r_train,
        r,
        lag_steps: lag,
        lag_samples: lag * w.b,
    }
}

/// U at fractional per-GPU batch (linear interpolation between integers).
fn u_frac(accel: &AccelModel, h: f64) -> f64 {
    if h <= 0.0 {
        return 0.0;
    }
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    if lo == hi || lo == 0 {
        return accel.u(hi.max(1));
    }
    let w = h - lo as f64;
    accel.u(lo) * (1.0 - w) + accel.u(hi) * w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conventional_throughput_rises_with_g() {
        let w = Workload::paper_a4();
        let r1 = conventional(&w, 1).r;
        let r8 = conventional(&w, 8).r;
        let r64 = conventional(&w, 64).r;
        assert!(r8 > r1, "more sequences per step -> better utilization");
        assert!(r64 > r8);
    }

    #[test]
    fn conventional_saturates() {
        // r_conv is increasing in G but hard-capped by the training side
        // (Eq. 13): the gap to r_train must shrink monotonically — the
        // "hard ceiling" of §3.
        let w = Workload::paper_a4();
        let r_train = w.n as f64 / w.tau;
        let (r128, r512, r2048) = (
            conventional(&w, 128).r,
            conventional(&w, 512).r,
            conventional(&w, 2048).r,
        );
        assert!(r128 < r512 && r512 < r2048, "increasing in G");
        assert!(r2048 < r_train, "never exceeds the train-side cap");
        assert!(
            (r_train - r2048) < (r_train - r512)
                && (r_train - r512) < (r_train - r128),
            "gap to the ceiling shrinks"
        );
        // relative growth per 4x of G slows down
        let rel_lo = r512 / r128;
        let rel_hi = r2048 / r512;
        assert!(rel_hi < rel_lo, "relative gains shrink: {rel_lo} vs {rel_hi}");
    }

    #[test]
    fn pipeline_case_study_matches_paper_a4() {
        // paper: H=192, I=44 -> r_gen = 16.9, r_train = 17.08, r = 16.9
        let w = Workload::paper_a4();
        let p = pipeline(&w, 44, 192);
        assert!((p.r_gen - 16.9).abs() < 0.5, "r_gen {}", p.r_gen);
        assert!((p.r_train - 17.08).abs() < 0.1, "r_train {}", p.r_train);
        assert!((p.r - 16.9).abs() < 0.5);
    }

    #[test]
    fn conventional_case_study_scale() {
        // paper A.4: r_conv = 10.7 with r_gen = 18.3, r_train = 26.02 at
        // the same-lag configuration (g_max ~ 133). r_train is exact (it
        // only involves N and tau); r_gen depends on the *measured* Fig 8
        // utilization table which we approximate analytically — accept the
        // shape within 20% (ours: ~21, the drain integral is sensitive to
        // the mid-range of U(h)).
        let w = Workload::paper_a4();
        let c = conventional(&w, 133);
        assert!((c.r_train - 26.02).abs() < 0.1, "r_train {}", c.r_train);
        assert!(
            (c.r_gen - 18.3).abs() / 18.3 < 0.20,
            "r_gen {} (paper 18.3)",
            c.r_gen
        );
        assert!((c.r - 10.7).abs() / 10.7 < 0.15, "r {} (paper 10.7)", c.r);
    }

    #[test]
    fn pipeline_train_side_caps() {
        let w = Workload::paper_a4();
        // huge I starves training
        let p = pipeline(&w, 120, 256);
        assert_eq!(p.r, p.r_train.min(p.r_gen));
        assert!(p.r_train < p.r_gen);
    }

    #[test]
    fn lag_grows_with_i_and_h() {
        let w = Workload::paper_a4();
        let a = pipeline(&w, 16, 64);
        let b = pipeline(&w, 32, 64);
        let c = pipeline(&w, 32, 128);
        assert!(b.lag_steps > a.lag_steps);
        assert!(c.lag_steps > b.lag_steps);
    }
}
