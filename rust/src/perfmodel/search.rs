//! Configuration search over (I, H) — the paper's A.3 note that the
//! same-lag maximization is hard analytically, so it performs "a
//! straight-forward search of all (H, I) configurations". Regenerates
//! Fig 9 and the A.4 case study, plus Fig 3b's Pareto view.

use super::throughput::{conventional, pipeline, ConvPoint, PipePoint, Workload};

/// Best pipeline throughput for every max-lag budget: for each (I, H)
/// with lag ≤ budget keep the max r. Returns (lag_budget, best point).
pub fn search_pipeline_configs(
    w: &Workload,
    lag_budgets: &[usize],
    h_grid: &[usize],
) -> Vec<(usize, Option<PipePoint>)> {
    let mut all: Vec<PipePoint> = Vec::new();
    for i in 1..w.n {
        for &h in h_grid {
            all.push(pipeline(w, i, h));
        }
    }
    lag_budgets
        .iter()
        .map(|&budget| {
            let best = all
                .iter()
                .filter(|p| p.lag_steps <= budget)
                .max_by(|a, b| a.r.partial_cmp(&b.r).unwrap());
            (budget, best.copied())
        })
        .collect()
}

/// Conventional curve over G (Fig 9's second series).
pub fn conventional_curve(w: &Workload, gs: &[usize]) -> Vec<ConvPoint> {
    gs.iter().map(|&g| conventional(w, g)).collect()
}

#[derive(Debug, Clone)]
pub struct CaseStudy {
    pub pipe: PipePoint,
    pub conv: ConvPoint,
    pub speedup: f64,
}

/// The A.4 case study: best same-lag pipeline config vs conventional at
/// the lag where pipeline peaks (paper: 1.57× at g_max ≈ 133).
pub fn case_study(w: &Workload) -> CaseStudy {
    let h_grid: Vec<usize> = (8..=512).step_by(4).collect();
    // find the pipeline config with max r whose lag matches a
    // conventional G in a practical range
    let mut best: Option<(PipePoint, ConvPoint, f64)> = None;
    for i in 1..w.n {
        for &h in &h_grid {
            let p = pipeline(w, i, h);
            if p.lag_steps == 0 || p.lag_steps > 512 {
                continue;
            }
            // same-lag conventional: S - 1 lag_samples ~ lag budget
            let g = p.lag_steps.max(1);
            let c = conventional(w, g);
            let speedup = p.r / c.r;
            if best.as_ref().map(|(_, _, s)| speedup > *s).unwrap_or(true) {
                best = Some((p, c, speedup));
            }
        }
    }
    let (pipe, conv, speedup) = best.expect("non-empty grid");
    CaseStudy { pipe, conv, speedup }
}

/// Fig 3b Pareto data: (effectiveness proxy, throughput) pairs for both
/// methods. Effectiveness ΔR/ΔS is not analytically computable (the
/// paper makes the same caveat); the standard proxy is 1/(1+mean_lag)
/// normalized — monotone in on-policyness.
pub fn pareto_sweep(w: &Workload) -> (Vec<(f64, f64)>, Vec<(f64, f64)>) {
    let conv: Vec<(f64, f64)> = [1usize, 2, 4, 8, 16, 32, 64, 128]
        .iter()
        .map(|&g| {
            let c = conventional(w, g);
            // mean lag of conventional batches ~ (G-1)/2 steps
            let eff = 1.0 / (1.0 + (g as f64 - 1.0) / 2.0);
            (eff, c.r)
        })
        .collect();
    let mut pipe: Vec<(f64, f64)> = Vec::new();
    for t_gpus in [16usize, 32, 48, 64, 80, 96, 112] {
        let i = w.n - t_gpus;
        // smallest H that keeps the trainer fed: U(H)*I >= (N-I)/tau
        let mut chosen: Option<PipePoint> = None;
        for h in (4..=1024).step_by(4) {
            let p = pipeline(w, i, h);
            if p.r_gen >= p.r_train {
                chosen = Some(p);
                break;
            }
        }
        if let Some(p) = chosen {
            // pipeline mean token lag ~ g_max/2 (linear ramp, Fig 3a)
            let eff = 1.0 / (1.0 + p.lag_steps as f64 / 2.0);
            pipe.push((eff, p.r));
        }
    }
    (pipe, conv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_study_speedup_matches_paper() {
        let w = Workload::paper_a4();
        let cs = case_study(&w);
        // paper: up to 1.57x at g_max ~ 133
        assert!(
            cs.speedup > 1.4 && cs.speedup < 1.8,
            "speedup {} (paper 1.57)",
            cs.speedup
        );
        assert!(
            (cs.pipe.lag_steps as f64 - 133.0).abs() < 60.0,
            "lag {} (paper ~133)",
            cs.pipe.lag_steps
        );
    }

    #[test]
    fn search_respects_lag_budget() {
        let w = Workload::paper_a4();
        let grid: Vec<usize> = (8..=256).step_by(8).collect();
        let res = search_pipeline_configs(&w, &[4, 16, 64, 256], &grid);
        let mut prev = 0.0;
        for (budget, best) in res {
            let p = best.expect("some config fits");
            assert!(p.lag_steps <= budget);
            assert!(p.r >= prev, "more lag budget can't hurt");
            prev = p.r;
        }
    }

    #[test]
    fn pipeline_dominates_conventional_at_matched_lag(){
        let w = Workload::paper_a4();
        for g in [16usize, 32, 64, 128] {
            let c = conventional(&w, g);
            let grid: Vec<usize> = (8..=512).step_by(8).collect();
            let best = search_pipeline_configs(&w, &[g], &grid)[0]
                .1
                .expect("config");
            assert!(
                best.r > c.r,
                "pipeline should win at lag {g}: {} vs {}",
                best.r,
                c.r
            );
        }
    }

    #[test]
    fn pareto_sweep_produces_both_frontiers() {
        let w = Workload::paper_a4();
        let (pipe, conv) = pareto_sweep(&w);
        assert!(pipe.len() >= 4 && conv.len() >= 4);
        // conventional frontier: throughput rises as effectiveness falls
        for win in conv.windows(2) {
            assert!(win[1].0 <= win[0].0, "conv eff monotone");
            assert!(win[1].1 >= win[0].1 * 0.99, "conv r monotone-ish");
        }
        // Fig 3b's claim, in its testable form: at matched lag budgets the
        // pipeline configurations reach strictly higher throughput, i.e.
        // higher eff x throughput iso-curves (checked in detail by
        // pipeline_dominates_conventional_at_matched_lag).
        let best_pipe_r = pipe.iter().map(|p| p.1).fold(0.0f64, f64::max);
        let conv_g32_r = conventional(&w, 32).r;
        assert!(best_pipe_r > conv_g32_r);
    }
}
