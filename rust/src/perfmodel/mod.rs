//! Analytic performance model — paper Appendix A, in "flash" units.
//!
//! A *flash* is the theoretically smallest amortized time for one token
//! forward pass (Eq. 9): F_gen / M. Throughputs are tokens/flash; they
//! depend only on the utilization curve U(h), the train-cost-per-token τ
//! and the topology — never on the absolute GPU speed, which is why the
//! paper's 128-H100 conclusions transfer to any accelerator.
//!
//! Regenerates: Fig 8 (U(h) curve), Fig 9 (throughput vs g_max with the
//! full (I, H) search), Fig 3b (Pareto schematic), and the Appendix A.4
//! case study (H=192, I=44, 1.57× at g_max≈133).

pub mod learning;
pub mod search;
pub mod throughput;
pub mod utilization;

pub use learning::{same_lag_comparison, LearnCfg, LearningCurve};
pub use search::{pareto_sweep, search_pipeline_configs, CaseStudy};
pub use throughput::{conventional, pipeline, ConvPoint, PipePoint, Workload};
pub use utilization::AccelModel;
