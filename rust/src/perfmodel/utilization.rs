//! GPU utilization model U(h) — paper Fig. 8.
//!
//! The paper measures H100 bf16 matmul utilization (as a fraction of
//! peak) for (4096, h)·(h, 16384) GEMMs, finding near-linear growth up
//! to h ≈ 200 with bumps where h is divisible by high powers of two, and
//! considers padding h up to h+64 when that raises effective speed.
//!
//! We model the envelope as a saturating exponential
//!     U_raw(h) = u_max · (1 − exp(−h / h0))
//! plus a divisibility bonus, and calibrate (u_max, h0) against the two
//! anchor points the paper quotes: U(192) ≈ 0.384 (the A.4 case study:
//! r_gen = U(192)·44 = 16.9) and the "almost linear up to 128–200"
//! behaviour of Fig 2a/Fig 8. Calibration notes: u_max = 0.75,
//! h0 = 279 give U_raw(192) = 0.384 including the 64-divisibility bump.

#[derive(Debug, Clone)]
pub struct AccelModel {
    pub u_max: f64,
    pub h0: f64,
    /// relative bonus for h divisible by 128 / 64 / 32
    pub bump128: f64,
    pub bump64: f64,
    pub bump32: f64,
    /// padding window the scheduler may round h up into (paper: +64)
    pub pad_window: usize,
}

impl AccelModel {
    /// Calibrated H100 model (see module docs).
    pub fn h100() -> Self {
        AccelModel {
            u_max: 0.75,
            h0: 279.0,
            bump128: 0.06,
            bump64: 0.03,
            bump32: 0.015,
            pad_window: 64,
        }
    }

    /// Raw utilization at batch h (no padding considered).
    pub fn u_raw(&self, h: usize) -> f64 {
        if h == 0 {
            return 0.0;
        }
        let base = self.u_max * (1.0 - (-(h as f64) / self.h0).exp());
        let bump = if h % 128 == 0 {
            self.bump128
        } else if h % 64 == 0 {
            self.bump64
        } else if h % 32 == 0 {
            self.bump32
        } else {
            0.0
        };
        (base * (1.0 + bump)).min(self.u_max)
    }

    /// Effective utilization with the paper's padding trick: run at the
    /// best h' in [h, h+pad_window], discounting the wasted columns.
    pub fn u(&self, h: usize) -> f64 {
        if h == 0 {
            return 0.0;
        }
        let mut best = self.u_raw(h);
        for pad in 1..=self.pad_window {
            let hp = h + pad;
            let eff = self.u_raw(hp) * (h as f64 / hp as f64);
            if eff > best {
                best = eff;
            }
        }
        best
    }

    /// Tokens/flash for one GPU decoding at batch h (= U(h), Eq. 17's
    /// per-GPU factor).
    pub fn tokens_per_flash(&self, h: usize) -> f64 {
        self.u(h)
    }

    /// The Fig 8 table: (h, U_raw, U_padded) rows.
    pub fn table(&self, hs: &[usize]) -> Vec<(usize, f64, f64)> {
        hs.iter().map(|&h| (h, self.u_raw(h), self.u(h))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_match_paper() {
        let m = AccelModel::h100();
        // A.4 case study: U(192) * 44 = 16.9 -> U(192) ~ 0.384
        let u192 = m.u_raw(192);
        assert!((u192 - 0.384).abs() < 0.01, "U(192) = {u192}");
    }

    #[test]
    fn monotone_and_bounded() {
        let m = AccelModel::h100();
        let mut prev = 0.0;
        for h in [1, 2, 4, 8, 16, 33, 64, 100, 128, 200, 256, 512, 1024, 4096] {
            let u = m.u(h);
            assert!(u >= prev - 0.03, "rough monotonicity at {h}: {u} < {prev}");
            assert!(u <= m.u_max + 1e-9);
            prev = u;
        }
        assert!(m.u(0) == 0.0);
    }

    #[test]
    fn near_linear_at_small_h() {
        let m = AccelModel::h100();
        // U(2h)/U(h) ~ 2 for small h (paper: linear up to ~128-200)
        let ratio = m.u_raw(64) / m.u_raw(32);
        assert!((ratio - 2.0).abs() < 0.25, "ratio {ratio}");
    }

    #[test]
    fn x_over_u_nearly_constant_at_small_x() {
        // the paper's formal explanation of conventional RL's inefficiency:
        // x / U(x) barely shrinks as x -> 0
        let m = AccelModel::h100();
        let f = |x: usize| x as f64 / m.u_raw(x);
        let f4 = f(4);
        let f16 = f(16);
        assert!(
            (f4 - f16).abs() / f16 < 0.05,
            "x/U(x) should be near-constant for small x: {f4} vs {f16}"
        );
    }

    #[test]
    fn padding_helps_at_odd_batch_sizes() {
        let m = AccelModel::h100();
        // just below a 128 multiple, padding up captures the bump
        assert!(m.u(127) >= m.u_raw(127));
        assert!(m.u(120) > m.u_raw(120));
    }
}
