//! Stream broker — the Redis substitute (paper Fig. 4).
//!
//! PipelineRL's three stages (actor → preprocessor → trainer) communicate
//! exclusively through named topics backed by bounded ring buffers. Two
//! overflow policies model the paper's design space:
//!
//! * [`Policy::Block`] — classic backpressure: publishers wait. Used on
//!   the trainer-facing topic so samples are never lost.
//! * [`Policy::DropOldest`] — the paper's "ring buffers to minimize the
//!   lag when earlier pipeline stages run faster than the later ones,
//!   e.g. when the trainer makes a checkpoint": the freshest samples
//!   survive, the stalest are evicted (they would have had the highest
//!   lag anyway).
//!
//! Topics are multi-producer/multi-consumer; consumers see FIFO order.
//! When every publisher is dropped, subscribers drain the queue and then
//! observe end-of-stream.

pub mod topic;

pub use topic::{topic, Policy, Publisher, RecvError, Subscriber, TopicStats};
