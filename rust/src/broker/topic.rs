//! Bounded MPMC ring-buffer topic (see module docs in broker/mod.rs).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Publisher blocks while the buffer is full (backpressure).
    Block,
    /// Evict the oldest queued item to make room (lag-minimizing ring).
    DropOldest,
}

#[derive(Debug, Default, Clone)]
pub struct TopicStats {
    pub published: u64,
    pub consumed: u64,
    pub dropped: u64,
    pub depth: usize,
    pub max_depth: usize,
}

struct Inner<T> {
    queue: VecDeque<T>,
    stats: TopicStats,
    capacity: usize,
    policy: Policy,
    publishers: usize,
}

struct Shared<T> {
    name: String,
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    subscribers: AtomicUsize,
    /// fault injection: publishers sleep until this instant before
    /// enqueueing (chaos-harness "topic stall"); None = healthy
    stall_until: Mutex<Option<Instant>>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum RecvError {
    /// All publishers dropped and the queue is drained.
    Closed,
    /// Timed out waiting for an item.
    Timeout,
}

/// Create a topic; returns connected (publisher, subscriber) handles.
/// Clone them freely for MPMC use.
pub fn topic<T>(name: &str, capacity: usize, policy: Policy) -> (Publisher<T>, Subscriber<T>) {
    assert!(capacity > 0, "topic capacity must be positive");
    let shared = Arc::new(Shared {
        name: name.to_string(),
        inner: Mutex::new(Inner {
            queue: VecDeque::with_capacity(capacity),
            stats: TopicStats::default(),
            capacity,
            policy,
            publishers: 1,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        subscribers: AtomicUsize::new(1),
        stall_until: Mutex::new(None),
    });
    (Publisher { shared: shared.clone() }, Subscriber { shared })
}

pub struct Publisher<T> {
    shared: Arc<Shared<T>>,
}

pub struct Subscriber<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for Publisher<T> {
    fn clone(&self) -> Self {
        self.shared.inner.lock().unwrap().publishers += 1;
        Publisher { shared: self.shared.clone() }
    }
}

impl<T> Drop for Publisher<T> {
    fn drop(&mut self) {
        let mut inner = self.shared.inner.lock().unwrap();
        inner.publishers -= 1;
        if inner.publishers == 0 {
            drop(inner);
            self.shared.not_empty.notify_all();
        }
    }
}

impl<T> Clone for Subscriber<T> {
    fn clone(&self) -> Self {
        self.shared.subscribers.fetch_add(1, Ordering::Relaxed);
        Subscriber { shared: self.shared.clone() }
    }
}

impl<T> Drop for Subscriber<T> {
    fn drop(&mut self) {
        if self.shared.subscribers.fetch_sub(1, Ordering::Relaxed) == 1 {
            // last subscriber gone: unblock publishers so they can error out
            self.shared.not_full.notify_all();
        }
    }
}

impl<T> Publisher<T> {
    pub fn name(&self) -> &str {
        &self.shared.name
    }

    /// Ring capacity (fixed at topic creation) — lets consumers of
    /// `stats().depth` express saturation as a fraction.
    pub fn capacity(&self) -> usize {
        self.shared.inner.lock().unwrap().capacity
    }

    /// Stall every publisher of this topic for `d` from now (chaos
    /// injection: models a broker hiccup / slow network). Send calls made
    /// while the stall is active sleep it off before enqueueing; consumers
    /// are unaffected and simply see no new items.
    pub fn stall_for(&self, d: Duration) {
        *self.shared.stall_until.lock().unwrap() = Some(Instant::now() + d);
    }

    /// Publish one item. With `Policy::Block` this waits for space; with
    /// `Policy::DropOldest` it evicts and returns the number dropped (0/1).
    pub fn send(&self, item: T) -> Result<u64, &'static str> {
        let stall = *self.shared.stall_until.lock().unwrap();
        if let Some(until) = stall {
            let now = Instant::now();
            if until > now {
                std::thread::sleep(until - now);
            }
        }
        let mut inner = self.shared.inner.lock().unwrap();
        let mut dropped = 0;
        loop {
            if inner.queue.len() < inner.capacity {
                break;
            }
            match inner.policy {
                Policy::DropOldest => {
                    inner.queue.pop_front();
                    inner.stats.dropped += 1;
                    dropped += 1;
                    break;
                }
                Policy::Block => {
                    if self.shared.subscribers.load(Ordering::Relaxed) == 0 {
                        return Err("all subscribers disconnected");
                    }
                    let (guard, _timeout) = self
                        .shared
                        .not_full
                        .wait_timeout(inner, Duration::from_millis(50))
                        .unwrap();
                    inner = guard;
                }
            }
        }
        inner.queue.push_back(item);
        inner.stats.published += 1;
        let depth = inner.queue.len();
        inner.stats.depth = depth;
        inner.stats.max_depth = inner.stats.max_depth.max(depth);
        drop(inner);
        self.shared.not_empty.notify_one();
        Ok(dropped)
    }

    pub fn stats(&self) -> TopicStats {
        let inner = self.shared.inner.lock().unwrap();
        let mut s = inner.stats.clone();
        s.depth = inner.queue.len();
        s
    }
}

impl<T> Subscriber<T> {
    pub fn name(&self) -> &str {
        &self.shared.name
    }

    /// Hot-attach a new publisher from the subscriber side, re-opening
    /// the topic even if the publisher count had reached zero —
    /// subscribers that already observed [`RecvError::Closed`] can keep
    /// calling `recv` and will see new items. The in-tree elastic pool
    /// hot-attaches by cloning a retained `Publisher` instead (see
    /// `coordinator::supervisor`); this is the primitive for embedders
    /// that only hold the subscriber end of a topic.
    pub fn make_publisher(&self) -> Publisher<T> {
        self.shared.inner.lock().unwrap().publishers += 1;
        Publisher { shared: self.shared.clone() }
    }

    /// Blocking receive with timeout.
    pub fn recv(&self, timeout: Duration) -> Result<T, RecvError> {
        let deadline = Instant::now() + timeout;
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.queue.pop_front() {
                inner.stats.consumed += 1;
                inner.stats.depth = inner.queue.len();
                drop(inner);
                self.shared.not_full.notify_one();
                return Ok(item);
            }
            if inner.publishers == 0 {
                return Err(RecvError::Closed);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            let (guard, _) = self
                .shared
                .not_empty
                .wait_timeout(inner, deadline - now)
                .unwrap();
            inner = guard;
        }
    }

    pub fn try_recv(&self) -> Result<T, RecvError> {
        self.recv(Duration::from_millis(0))
    }

    /// Receive up to `n` items, waiting up to `timeout` for the *first*.
    pub fn recv_up_to(&self, n: usize, timeout: Duration) -> Result<Vec<T>, RecvError> {
        let mut out = Vec::with_capacity(n);
        match self.recv(timeout) {
            Ok(x) => out.push(x),
            Err(e) => return Err(e),
        }
        while out.len() < n {
            match self.try_recv() {
                Ok(x) => out.push(x),
                Err(_) => break,
            }
        }
        Ok(out)
    }

    /// Receive exactly `n` items, waiting up to `timeout` overall.
    /// Returns what was collected on timeout/close.
    pub fn recv_exact(&self, n: usize, timeout: Duration) -> Vec<T> {
        let deadline = Instant::now() + timeout;
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.recv(deadline - now) {
                Ok(x) => out.push(x),
                Err(_) => break,
            }
        }
        out
    }

    pub fn depth(&self) -> usize {
        self.shared.inner.lock().unwrap().queue.len()
    }

    pub fn stats(&self) -> TopicStats {
        let inner = self.shared.inner.lock().unwrap();
        let mut s = inner.stats.clone();
        s.depth = inner.queue.len();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order() {
        let (tx, rx) = topic("t", 16, Policy::Block);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        for i in 0..10 {
            assert_eq!(rx.recv(Duration::from_secs(1)).unwrap(), i);
        }
    }

    #[test]
    fn drop_oldest_keeps_freshest() {
        let (tx, rx) = topic("t", 3, Policy::DropOldest);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let got: Vec<i32> = rx.recv_exact(3, Duration::from_millis(100));
        assert_eq!(got, vec![7, 8, 9]);
        assert_eq!(rx.stats().dropped, 7);
    }

    #[test]
    fn close_on_publisher_drop() {
        let (tx, rx) = topic("t", 4, Policy::Block);
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv(Duration::from_secs(1)).unwrap(), 1);
        assert_eq!(rx.recv(Duration::from_secs(1)), Err(RecvError::Closed));
    }

    #[test]
    fn timeout_when_empty() {
        let (_tx, rx) = topic::<i32>("t", 4, Policy::Block);
        assert_eq!(
            rx.recv(Duration::from_millis(20)),
            Err(RecvError::Timeout)
        );
    }

    #[test]
    fn blocking_backpressure() {
        let (tx, rx) = topic("t", 2, Policy::Block);
        tx.send(0).unwrap();
        tx.send(1).unwrap();
        let t = thread::spawn(move || {
            tx.send(2).unwrap(); // must block until a recv happens
            tx.stats().published
        });
        thread::sleep(Duration::from_millis(50));
        assert_eq!(rx.recv(Duration::from_secs(1)).unwrap(), 0);
        assert_eq!(t.join().unwrap(), 3);
        assert_eq!(rx.recv(Duration::from_secs(1)).unwrap(), 1);
        assert_eq!(rx.recv(Duration::from_secs(1)).unwrap(), 2);
    }

    #[test]
    fn mpmc_delivers_everything_once() {
        let (tx, rx) = topic("t", 8, Policy::Block);
        let n_pub = 4;
        let n_per = 250;
        let mut pubs = Vec::new();
        for p in 0..n_pub {
            let tx = tx.clone();
            pubs.push(thread::spawn(move || {
                for i in 0..n_per {
                    tx.send(p * n_per + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut subs = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            subs.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Ok(x) = rx.recv(Duration::from_secs(5)) {
                    got.push(x);
                }
                got
            }));
        }
        drop(rx);
        for p in pubs {
            p.join().unwrap();
        }
        let mut all: Vec<i32> = subs.into_iter().flat_map(|s| s.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..n_pub * n_per).collect::<Vec<_>>());
    }

    #[test]
    fn hot_attach_reopens_closed_topic() {
        let (tx, rx) = topic("t", 4, Policy::Block);
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv(Duration::from_secs(1)).unwrap(), 1);
        assert_eq!(rx.recv(Duration::from_millis(10)), Err(RecvError::Closed));
        // elastic pool: a new actor attaches after all publishers died
        let tx2 = rx.make_publisher();
        tx2.send(2).unwrap();
        assert_eq!(rx.recv(Duration::from_secs(1)).unwrap(), 2);
        drop(tx2);
        assert_eq!(rx.recv(Duration::from_millis(10)), Err(RecvError::Closed));
    }

    #[test]
    fn stall_delays_publishers_only() {
        let (tx, rx) = topic("t", 8, Policy::Block);
        tx.send(0).unwrap();
        tx.stall_for(Duration::from_millis(80));
        // consumer is unaffected by the stall
        assert_eq!(rx.recv(Duration::from_millis(10)).unwrap(), 0);
        let t0 = Instant::now();
        tx.send(1).unwrap();
        assert!(
            t0.elapsed() >= Duration::from_millis(60),
            "send must sleep off the stall"
        );
        // stall expired: sends proceed (no upper-bound assert — loaded
        // CI runners make tight wall-clock ceilings flaky)
        tx.send(2).unwrap();
        assert_eq!(rx.recv(Duration::from_secs(1)).unwrap(), 1);
        assert_eq!(rx.recv(Duration::from_secs(1)).unwrap(), 2);
    }

    #[test]
    fn recv_up_to_batches() {
        let (tx, rx) = topic("t", 16, Policy::Block);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let batch = rx.recv_up_to(3, Duration::from_secs(1)).unwrap();
        assert_eq!(batch, vec![0, 1, 2]);
        let batch = rx.recv_up_to(10, Duration::from_secs(1)).unwrap();
        assert_eq!(batch, vec![3, 4]);
    }

    #[test]
    fn max_depth_tracked() {
        let (tx, rx) = topic("t", 8, Policy::Block);
        for i in 0..6 {
            tx.send(i).unwrap();
        }
        let _ = rx.recv(Duration::from_secs(1));
        assert_eq!(rx.stats().max_depth, 6);
    }
}
