//! Offline stand-in for the `xla` crate (xla-rs PJRT bindings).
//!
//! Mirrors exactly the subset of the xla-rs API that `pipeline_rl` calls:
//!
//! * [`Literal`] — a *functional* host-side tensor literal (scalar / vec1 /
//!   reshape / readback / tuples), so everything that moves data around in
//!   host memory works identically to the real bindings;
//! * [`PjRtClient`] and friends — the device entry points. Constructing a
//!   client **fails** with a descriptive error: there is no XLA runtime in
//!   this build. Callers are expected to gate on that error (see
//!   `pipeline_rl::runtime::runtime_available`).
//!
//! Swap in the real bindings by replacing the `xla = { path = ... }`
//! dependency with the upstream git dependency; no source changes needed.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type matching the shape of xla-rs's error (Display + StdError).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} unavailable: pipeline_rl was built against the vendored \
         no-PJRT xla stub (rust/vendor/xla). Install the real xla-rs \
         bindings and the AOT artifacts to run device code."
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
    U8,
}

/// Sealed-ish conversion trait for the element types the stub supports.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn store(data: Vec<Self>) -> LitData;
    fn load(data: &LitData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn store(data: Vec<f32>) -> LitData {
        LitData::F32(data)
    }
    fn load(data: &LitData) -> Option<Vec<f32>> {
        match data {
            LitData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn store(data: Vec<i32>) -> LitData {
        LitData::I32(data)
    }
    fn load(data: &LitData) -> Option<Vec<i32>> {
        match data {
            LitData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub enum LitData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side tensor literal. Fully functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: LitData,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: vec![], data: T::store(vec![v]) }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::store(v.to_vec()) }
    }

    /// Build a tuple literal from element literals (the shape the decode
    /// graph's `return_tuple=True` lowering produces). Fully functional in
    /// the stub so the runtime's tuple-readback fallback path — and the
    /// selective-readback logic layered on it — can be tested device-free.
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { dims: vec![elements.len() as i64], data: LitData::Tuple(elements) }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error(format!(
                "reshape mismatch: literal has {have} elements, target shape {dims:?} wants {want}"
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LitData::F32(v) => v.len(),
            LitData::I32(v) => v.len(),
            LitData::Tuple(_) => 0,
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            LitData::F32(_) => ElementType::F32,
            LitData::I32(_) => ElementType::S32,
            LitData::Tuple(_) => return Err(Error("tuple literal has no array shape".into())),
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::load(&self.data).ok_or_else(|| Error("literal element type mismatch".into()))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            LitData::Tuple(xs) => Ok(xs.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// PJRT client handle. `cpu()` always errors in the stub build.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PJRT CPU client"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PJRT buffer staging"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("XLA compilation"))
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device readback"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _inputs: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executable dispatch"))
    }
}

/// Parsed HLO-text module. The stub keeps the raw text only.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path:?}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        assert!(Literal::vec1(&[1i32, 2, 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn pjrt_is_gated() {
        assert!(PjRtClient::cpu().is_err());
    }

    #[test]
    fn tuple_roundtrip() {
        let t = Literal::tuple(vec![Literal::vec1(&[1i32, 2]), Literal::scalar(3.0f32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<i32>().unwrap(), vec![1, 2]);
        assert_eq!(parts[1].to_vec::<f32>().unwrap(), vec![3.0]);
        assert!(t.array_shape().is_err(), "tuple literal has no array shape");
    }
}
