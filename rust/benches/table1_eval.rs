//! Table 1 — held-out success rates: base model vs SFT vs PipelineRL,
//! per task family (our MATH500 / AIME24 stand-ins). Shortened run; the
//! full experiment is `examples/evaluate.rs`.
//!
//! `cargo bench --bench table1_eval`

use pipeline_rl::benchkit;
use pipeline_rl::config::RunConfig;
use pipeline_rl::coordinator::{self, eval};
use pipeline_rl::data::task::TaskKind;
use pipeline_rl::metrics::MetricsHub;
use pipeline_rl::runtime::Runtime;
use pipeline_rl::util::logging::{self, Level};

fn main() -> anyhow::Result<()> {
    logging::set_level(Level::Warn);
    benchkit::section("Table 1 — success rates (tiny variant, shortened)");

    let mut cfg = RunConfig::default();
    cfg.variant = "tiny".into();
    // configuration validated in examples/evaluate: a strong-enough warmup
    // is required or short RL runs collapse into the length-penalty
    // optimum (emit EOS early) before reward signal accumulates
    cfg.sft_steps = 500;
    cfg.rl_steps = 30;
    cfg.max_new_tokens = 24;
    cfg.task.kinds = vec![TaskKind::Add, TaskKind::Sub, TaskKind::Copy];
    cfg.task.max_operand = 20;
    cfg.log_every = 0;
    cfg.seed = 2;
    let n_eval = 60;

    let mut rt = Runtime::new()?;
    let base_params = rt.init_params(&cfg.variant, cfg.seed as i32)?;
    let rep_base = eval::evaluate(&mut rt, &cfg, &base_params, n_eval)?;

    let hub = MetricsHub::new();
    let sft_params = coordinator::warmup::run_sft(&mut rt, &cfg, &hub)?;
    let rep_sft = eval::evaluate(&mut rt, &cfg, &sft_params, n_eval)?;

    let summary = coordinator::run(cfg.clone(), Some(sft_params))?;
    let rep_rl = eval::evaluate(&mut rt, &cfg, &summary.final_params, n_eval)?;
    let samples = summary
        .report
        .counters
        .get("samples_trained")
        .copied()
        .unwrap_or(0.0);

    let row = |name: &str, rep: &eval::EvalReport, samples: String| {
        vec![
            name.to_string(),
            format!("{:.1}%", 100.0 * rep.success_rate()),
            samples,
            format!("{:.1}", rep.mean_gen_len),
            format!("{:.2}", rep.eos_rate),
        ]
    };
    benchkit::table(
        &["method", "success", "# samples", "mean len", "eos rate"],
        &[
            row("base (random init)", &rep_base, "-".into()),
            row("SFT warmup", &rep_sft, "-".into()),
            row("PipelineRL", &rep_rl, format!("{samples}")),
        ],
    );
    println!(
        "\nshape check (paper Table 1): the robust signals at this bench's\n\
         short budget are base -> SFT (0% -> formatted answers) and RL\n\
         driving the eos rate to ~1 while train reward rises; the held-out\n\
         delta of a 30-step RL run sits within eval noise (+-2/60) — the\n\
         headline run (EXPERIMENTS.md) shows the reward-vs-time curves\n\
         where the PipelineRL-vs-conventional comparison actually lives."
    );
    Ok(())
}
