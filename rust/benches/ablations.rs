//! Ablations over the design choices DESIGN.md calls out:
//!
//! * advantage estimator — group baseline (GRPO-style) vs normalized
//!   group vs learned value head (Eq. 4);
//! * rollout-queue overflow policy — the paper's lag-minimizing
//!   DropOldest ring vs plain Block backpressure;
//! * KV handling at in-flight updates — retain (paper's choice) vs
//!   recompute: the throughput cost the §5.1 discussion quantifies.
//!
//! `cargo bench --bench ablations`

use pipeline_rl::benchkit;
use pipeline_rl::broker::Policy;
use pipeline_rl::config::RunConfig;
use pipeline_rl::coordinator;
use pipeline_rl::data::task::{TaskGen, TaskKind};
use pipeline_rl::engine::{Engine, EngineCfg};
use pipeline_rl::metrics::MetricsHub;
use pipeline_rl::model::Tokenizer;
use pipeline_rl::rl::AdvantageMode;
use pipeline_rl::runtime::Runtime;
use pipeline_rl::util::logging::{self, Level};
use pipeline_rl::util::timer::Stopwatch;
use pipeline_rl::util::Rng;

fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.variant = "tiny".into();
    cfg.rl_steps = 16;
    cfg.sft_steps = 60;
    cfg.group_size = 4;
    cfg.max_new_tokens = 24;
    cfg.task.kinds = vec![TaskKind::Copy, TaskKind::Add];
    cfg.task.max_operand = 20;
    cfg.log_every = 0;
    cfg.seed = 21;
    cfg
}

fn main() -> anyhow::Result<()> {
    logging::set_level(Level::Warn);
    let base = base_cfg();
    let warm = {
        let mut rt = Runtime::new()?;
        let hub = MetricsHub::new();
        coordinator::warmup::run_sft(&mut rt, &base, &hub)?
    };

    benchkit::section("ablation 1 — advantage estimator");
    let mut rows = Vec::new();
    for (name, mode, vf) in [
        ("group", AdvantageMode::Group, 0.0),
        ("group_norm", AdvantageMode::GroupNormalized, 0.0),
        ("value (Eq. 4)", AdvantageMode::Value, 0.5),
    ] {
        let mut cfg = base.clone();
        cfg.advantage = mode;
        cfg.vf_coef = vf;
        let s = coordinator::run(cfg, Some(warm.clone()))?;
        rows.push(vec![
            name.to_string(),
            benchkit::f3(
                s.report
                    .series("reward_vs_samples")
                    .map(|r| r.tail_mean(5))
                    .unwrap_or(f64::NAN),
            ),
            benchkit::f3(
                s.report.series("train/ess").map(|r| r.tail_mean(5)).unwrap_or(f64::NAN),
            ),
            benchkit::f3(
                s.report
                    .series("train/v_loss")
                    .map(|r| r.tail_mean(5))
                    .unwrap_or(f64::NAN),
            ),
        ]);
    }
    benchkit::table(&["advantage", "reward (tail)", "ESS", "v_loss"], &rows);

    benchkit::section("ablation 2 — rollout queue policy under a slow trainer");
    let mut rows = Vec::new();
    for (name, policy, cap) in [
        ("drop_oldest (ring, paper)", Policy::DropOldest, 16usize),
        ("block (backpressure)", Policy::Block, 16),
    ] {
        let mut cfg = base.clone();
        cfg.rollout_policy = policy;
        cfg.rollout_queue = cap;
        cfg.checkpoint.every = 0;
        let s = coordinator::run(cfg, Some(warm.clone()))?;
        rows.push(vec![
            name.to_string(),
            format!("{:.0}", s.report.counters.get("rollouts_dropped_ring").copied().unwrap_or(0.0)),
            benchkit::f3(
                s.report
                    .series("train/mean_lag")
                    .map(|r| r.tail_mean(5))
                    .unwrap_or(f64::NAN),
            ),
            format!("{:.1}", s.wall_seconds),
        ]);
    }
    benchkit::table(&["policy", "dropped", "mean lag (tail)", "wall (s)"], &rows);

    benchkit::section("ablation 3 — KV retain vs recompute at weight updates");
    let mut rows = Vec::new();
    for (name, recompute) in [("retain (paper)", false), ("recompute", true)] {
        let mut rt = Runtime::new()?;
        let params = rt.init_params("tiny", 1)?;
        let mut ecfg = EngineCfg::new("tiny");
        ecfg.max_new_tokens = 40;
        ecfg.recompute_kv_on_update = recompute;
        let mut eng = Engine::new(&mut rt, ecfg, &params, 0, Rng::new(4))?;
        eng.set_weights(1, &params)?;
        let gen = TaskGen::curriculum_small();
        let tk = Tokenizer::new();
        for i in 0..16 {
            let p = gen.problem(i as u64);
            let toks = tk.encode(&p.prompt).unwrap();
            eng.add_request(p, toks, i as u64);
        }
        let sw = Stopwatch::new();
        let mut ver = 1;
        let mut steps = 0u64;
        while eng.load() > 0 && steps < 800 {
            eng.step()?;
            steps += 1;
            if steps % 8 == 0 {
                ver += 1;
                eng.set_weights(ver, &params)?; // in-flight update every 8 steps
            }
        }
        let secs = sw.seconds();
        rows.push(vec![
            name.to_string(),
            format!("{}", eng.stats.tokens_sampled),
            format!("{}", eng.stats.recompute_steps),
            format!("{:.0}", eng.stats.tokens_sampled as f64 / secs),
        ]);
    }
    benchkit::table(
        &["kv policy", "tokens", "replay steps", "tokens/s"],
        &rows,
    );
    println!(
        "\nshape check (paper §5.1/Fig 7): recompute costs extra replay decode\n\
         steps (lower throughput) for a negligible KL improvement."
    );
    Ok(())
}
