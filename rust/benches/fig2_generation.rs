//! Fig 2 — analysis of generation times and throughput.
//!
//! (a) throughput vs generation batch size: analytic U(h)·h per-GPU
//!     tokens/flash (the paper's H100 measurement) AND the real engine's
//!     measured decode throughput on this box's CPU PJRT backend;
//! (b) inference batch size vs time: the live-batch drain trajectory as
//!     an engine finishes a fixed request set;
//! (c) time-to-finish and tokens/s vs sequences per GPU.
//!
//! `cargo bench --bench fig2_generation`

use pipeline_rl::benchkit;
use pipeline_rl::data::task::TaskGen;
use pipeline_rl::engine::{Engine, EngineCfg};
use pipeline_rl::model::Tokenizer;
use pipeline_rl::perfmodel::AccelModel;
use pipeline_rl::runtime::Runtime;
use pipeline_rl::simcluster::{drain_scenario, generation_only};
use pipeline_rl::util::timer::Stopwatch;
use pipeline_rl::util::Rng;

fn main() -> anyhow::Result<()> {
    let accel = AccelModel::h100();

    benchkit::section("Fig 2a — generation throughput vs batch size");
    println!("analytic (H100 model), per GPU:");
    let rows: Vec<Vec<String>> = [1usize, 2, 4, 8, 16, 32, 64, 128, 192, 256, 384, 512]
        .iter()
        .map(|&h| {
            vec![
                h.to_string(),
                benchkit::f3(accel.u(h)),
                benchkit::f(accel.u(h) * 1.0 / 1.0),
            ]
        })
        .collect();
    benchkit::table(&["batch h", "U(h)", "tokens/flash"], &rows);

    println!("\nmeasured (tiny variant, CPU PJRT decode, forced tokens):");
    let mut rt = Runtime::new()?;
    let variant = rt.manifest.variant("tiny")?.clone();
    let mut rows = Vec::new();
    for &fill in &[1usize, 2, 4] {
        let fill = fill.min(variant.gen_batch);
        let mut cfg = EngineCfg::new("tiny");
        cfg.max_new_tokens = 16;
        let params = rt.init_params("tiny", 1)?;
        let mut eng = Engine::new(&mut rt, cfg, &params, 0, Rng::new(9))?;
        eng.set_weights(1, &params)?;
        let gen = TaskGen::curriculum_small();
        let tk = Tokenizer::new();
        for i in 0..fill {
            let p = gen.problem(i as u64);
            let toks = tk.encode(&p.prompt).unwrap();
            eng.add_request(p, toks, i as u64);
        }
        // warmup (compilation already cached by Runtime) then measure
        let sw = Stopwatch::new();
        let mut steps = 0u64;
        while eng.n_active() > 0 || eng.n_pending() > 0 {
            eng.step()?;
            steps += 1;
            if steps > 2000 {
                break;
            }
        }
        let secs = sw.seconds();
        let toks = eng.stats.tokens_sampled + eng.stats.tokens_forced;
        rows.push(vec![
            fill.to_string(),
            format!("{steps}"),
            format!("{:.1}", toks as f64 / secs),
        ]);
    }
    benchkit::table(&["live seqs", "steps", "tokens/s (CPU)"], &rows);

    benchkit::section("Fig 2b — inference batch size vs time (batch drain)");
    let (series, t_total, thr) = generation_only(&accel, 256, 2048, 512, 11);
    let xs: Vec<f64> = series.points.iter().map(|p| p.t).collect();
    let vs: Vec<f64> = series.points.iter().map(|p| p.value).collect();
    benchkit::series("live sequences vs time (flashes), H=256, 2048 seqs", &xs, &vs, 12);
    println!("total: {t_total:.0} flashes, {thr:.2} tokens/flash");

    benchkit::section("Fig 2c — time to finish / throughput vs seqs per GPU");
    let pts = drain_scenario(&accel, 512, 512, &[16, 32, 64, 128, 256, 512, 1024]);
    let rows: Vec<Vec<String>> = pts
        .iter()
        .map(|p| {
            vec![
                p.seqs_per_gpu.to_string(),
                format!("{:.0}", p.time_flashes),
                benchkit::f(p.tokens_per_flash),
            ]
        })
        .collect();
    benchkit::table(&["seqs/GPU", "time (flashes)", "tokens/flash"], &rows);
    println!(
        "\nshape check (paper): time plateaus as seqs/GPU shrinks; throughput\n\
         keeps falling — the reason conventional RL wants many seqs per GPU."
    );
    Ok(())
}
