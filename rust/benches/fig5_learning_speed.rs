//! Fig 5 — learning speed: PipelineRL vs Conventional RL.
//!
//! Two parts:
//! (1) REAL stack (tiny variant, shortened): pipeline vs conventional
//!     from the same SFT warmup — reward-vs-time / reward-vs-samples /
//!     samples-vs-time series, printed the way Fig 5 plots them.
//! (2) Cluster scale (flash-unit simulator, 128 GPUs, B=128): wall-clock
//!     to a fixed number of optimizer steps / samples — the paper's ~2x
//!     headline vs the best stable G.
//!
//! Fig 10's probe (G=64 instability) is exercised by the real stack in
//! `fig6_onpolicyness` (ESS collapse) — at our scale the divergence shows
//! up as ESS decay rather than hard NaNs within a short run.
//!
//! `cargo bench --bench fig5_learning_speed`

use pipeline_rl::benchkit;
use pipeline_rl::config::{Mode, RunConfig};
use pipeline_rl::coordinator;
use pipeline_rl::data::task::TaskKind;
use pipeline_rl::metrics::MetricsHub;
use pipeline_rl::runtime::Runtime;
use pipeline_rl::perfmodel::{same_lag_comparison, throughput::Workload, LearnCfg};
use pipeline_rl::simcluster::{SimCfg, Simulator};
use pipeline_rl::util::logging::{self, Level};

fn main() -> anyhow::Result<()> {
    logging::set_level(Level::Warn);

    benchkit::section("Fig 5 (real stack, tiny variant, 24 optimizer steps)");
    let mut base = RunConfig::default();
    base.variant = "tiny".into();
    base.rl_steps = 24;
    base.sft_steps = 60;
    base.group_size = 4;
    base.max_new_tokens = 24;
    base.task.kinds = vec![TaskKind::Copy, TaskKind::Add];
    base.task.max_operand = 20;
    base.log_every = 0;
    base.seed = 11;

    // shared warmup: identical starting policy for both modes
    let warm = {
        let mut rt = Runtime::new()?;
        let hub = MetricsHub::new();
        coordinator::warmup::run_sft(&mut rt, &base, &hub)?
    };

    let mut rows = Vec::new();
    // periodic k=4 sits between the two: pipeline-style overlap, but
    // weights publish only every 4th optimizer step
    for mode in [
        Mode::Pipeline,
        Mode::Periodic { k: 4 },
        Mode::Conventional { g: 4 },
    ] {
        let mut cfg = base.clone();
        cfg.mode = mode;
        let s = coordinator::run(cfg.clone(), Some(warm.clone()))?;
        let rvt = s.report.series("reward_vs_time").cloned().unwrap_or_default();
        let svt = s.report.series("samples_vs_time").cloned().unwrap_or_default();
        println!("\n-- mode {} --", cfg.mode.name());
        benchkit::series(
            "Fig 5a reward vs wall-clock (s)",
            &rvt.points.iter().map(|p| p.t).collect::<Vec<_>>(),
            &rvt.points.iter().map(|p| p.value).collect::<Vec<_>>(),
            8,
        );
        benchkit::series(
            "Fig 5c samples vs wall-clock (s)",
            &svt.points.iter().map(|p| p.t).collect::<Vec<_>>(),
            &svt.points.iter().map(|p| p.value).collect::<Vec<_>>(),
            8,
        );
        rows.push(vec![
            cfg.mode.name(),
            format!("{:.1}", s.wall_seconds),
            format!("{}", s.report.counters.get("samples_trained").copied().unwrap_or(0.0)),
            format!(
                "{:.2}",
                s.report.counters.get("samples_trained").copied().unwrap_or(0.0)
                    / s.wall_seconds
            ),
        ]);
    }
    println!();
    benchkit::table(&["mode", "wall (s)", "samples", "samples/s"], &rows);

    benchkit::section("Fig 5c (cluster scale: N=128, B=128, simulator)");
    let steps = 64;
    let mut rows = Vec::new();
    // PipelineRL at the A.4-style configuration
    let mut pcfg = SimCfg::pipeline(128, 44, 192, 128, 512);
    pcfg.rl_steps = steps;
    let rp = Simulator::new(pcfg).run();
    rows.push(vec![
        "pipeline (I=44,H=192)".to_string(),
        format!("{:.0}", rp.t_end),
        format!("{:.2}", rp.throughput),
        "1.00".into(),
    ]);
    for g in [8usize, 16, 32] {
        let mut ccfg = SimCfg::conventional(128, g, 64, 128, 512);
        ccfg.rl_steps = steps;
        let rc = Simulator::new(ccfg).run();
        rows.push(vec![
            format!("conventional G={g}"),
            format!("{:.0}", rc.t_end),
            format!("{:.2}", rc.throughput),
            format!("{:.2}", rc.t_end / rp.t_end),
        ]);
    }
    benchkit::table(
        &["method", "time for 64 steps (flashes)", "tokens/flash", "slowdown vs pipeline"],
        &rows,
    );
    println!("\nshape check (paper Fig 5): PipelineRL reaches the same number of");
    println!("optimizer steps/samples ~2x faster than the best stable G=32 baseline.");

    benchkit::section("supplementary — same-g_max learning-speed simulation");
    let w = Workload::paper_a4();
    let lc = LearnCfg::default();
    let mut rows = Vec::new();
    for g in [32usize, 64, 133, 256] {
        let (p, c, speedup) = same_lag_comparison(&w, &lc, g);
        rows.push(vec![
            g.to_string(),
            format!("{:.0}", p.time_to(lc.r_max * 0.5).unwrap_or(f64::NAN)),
            format!("{:.0}", c.time_to(lc.r_max * 0.5).unwrap_or(f64::NAN)),
            format!("{speedup:.2}"),
        ]);
    }
    benchkit::table(
        &["g_max", "pipeline t(R=.4)", "conventional t(R=.4)", "speedup"],
        &rows,
    );
    println!("\n(paper supplementary: ~1.5x faster at the same maximum lag)");
    Ok(())
}
