//! Scheduler / migration / autoscale benchmarks — appended
//! machine-readably to BENCH_sched.json (see benchkit docs).
//!
//! * snapshot export/import cost: serialize + deserialize + SeqState
//!   rebuild across prefix lengths (the per-sequence price of a kill or
//!   descale hand-off — entirely device-free);
//! * autoscaler reaction time in the simulated cluster: flashes from the
//!   outage that creates the rollout-queue backlog to the first spare
//!   activation, plus the full add/remove trajectory;
//! * `decide()` throughput (the supervisor-poll hot cost).
//!
//! `cargo bench --bench sched`

use pipeline_rl::benchkit::{self, time};
use pipeline_rl::data::task::TaskGen;
use pipeline_rl::engine::SeqState;
use pipeline_rl::sched::{AutoScaleCfg, AutoScaler, ScaleSignals, SeqSnapshot};
use pipeline_rl::simcluster::{GpuFailure, SimAutoScale, SimCfg, Simulator};

fn snapshot_with(gen: usize) -> SeqSnapshot {
    SeqSnapshot {
        seq_id: 42,
        group_id: (3u64 << 40) | 7,
        problem_id: 5,
        prompt: vec![1; 16],
        gen_tokens: (0..gen as i32).collect(),
        behavior_lp: vec![-0.5; gen],
        token_version: (0..gen as u64).collect(),
        pos: if gen == 0 { 0 } else { 15 + gen },
        max_new: gen + 8,
        rng_words: [1, 2, 3, 4],
        t_start: 0.0,
    }
}

fn autoscaled_cluster() -> SimCfg {
    // mirror of the sim acceptance scenario: 6/8 generation GPUs go dark
    // at flash 50, flooding the regen queue; spares absorb the backlog
    // and retire once the victims recover and the trainer inbox saturates
    let mut c = SimCfg::pipeline(16, 8, 32, 64, 128);
    c.rl_steps = 60;
    c.migrate = true;
    c.tau = 12.0;
    c.failures = (0..6)
        .map(|g| GpuFailure { gpu: g, at: 50.0, down_for: 3000.0 })
        .collect();
    c.autoscale = Some(SimAutoScale {
        cfg: AutoScaleCfg {
            enabled: true,
            backlog_per_actor: 1.0,
            supply_high_frac: 0.75,
            up_patience: 2,
            down_patience: 3,
            cooldown: 2,
            max_lag_steps: 0.0,
            ess_floor: 0.0,
            min_batch_fill: 0.0,
            eval_every_ms: 0,
        },
        max_extra_gpus: 4,
        eval_every_flashes: 20.0,
        supply_capacity: 256,
    });
    c
}

fn main() {
    benchkit::json_begin("sched");

    benchkit::section("sched — snapshot export/import cost");
    let problem = TaskGen::curriculum_small().problem(5);
    for &n in &[16usize, 256, 4096] {
        let snap = snapshot_with(n);
        let bytes = snap.to_bytes();
        benchkit::json_note(
            &format!("snapshot serialize ({n} gen tokens)/bytes"),
            bytes.len() as f64,
        );
        time(&format!("snapshot serialize ({n} gen tokens)"), 10, 200, || {
            std::hint::black_box(snap.to_bytes());
        });
        time(&format!("snapshot deserialize ({n} gen tokens)"), 10, 200, || {
            std::hint::black_box(SeqSnapshot::from_bytes(&bytes).unwrap());
        });
        time(&format!("snapshot import rebuild ({n} gen tokens)"), 10, 200, || {
            std::hint::black_box(SeqState::from_snapshot(&snap, 1, problem.clone(), 0.0));
        });
    }

    benchkit::section("sched — autoscaler reaction time (simulated cluster)");
    {
        let r = Simulator::new(autoscaled_cluster()).run();
        let outage_at = 50.0;
        let reaction = r
            .scaleup_times
            .first()
            .map(|&t| t - outage_at)
            .unwrap_or(f64::NAN);
        println!(
            "outage at {outage_at} flashes -> first spare at {:?} (reaction {reaction:.1} \
             flashes); {} adds / {} removes, {} seqs migrated, {:.0} tokens salvaged",
            r.scaleup_times.first(),
            r.gpus_added,
            r.gpus_removed,
            r.seqs_migrated,
            r.tokens_salvaged,
        );
        benchkit::json_note("autoscale/reaction_flashes", reaction);
        benchkit::json_note("autoscale/gpus_added", r.gpus_added as f64);
        benchkit::json_note("autoscale/gpus_removed", r.gpus_removed as f64);
        benchkit::json_note("autoscale/seqs_migrated", r.seqs_migrated as f64);
        benchkit::json_note("autoscale/tokens_salvaged", r.tokens_salvaged);
        benchkit::json_note("autoscale/sim_t_end_flashes", r.t_end);
    }

    benchkit::section("sched — decision-loop cost");
    {
        let mut scaler = AutoScaler::new(AutoScaleCfg::default());
        let sig = ScaleSignals {
            backlog: 5,
            supply_depth: 100,
            supply_capacity: 256,
            token_lag: 1.5,
            ess: 1.0,
            batch_fill: 0.9,
            pool: 4,
        };
        time("autoscaler decide()", 100, 2000, || {
            std::hint::black_box(scaler.decide(&sig));
        });
    }

    if let Some(p) = benchkit::json_end() {
        println!("results -> {}", p.display());
    }
}
