//! Fig 9 + Fig 3b + Appendix A.4 — throughput vs max lag with the full
//! (I, H) configuration search, the Pareto frontier points, and the case
//! study. Also cross-checks the analytic pipeline model against the
//! discrete-event simulator (queueing effects included).
//!
//! `cargo bench --bench fig9_pareto`

use pipeline_rl::benchkit;
use pipeline_rl::perfmodel::{search, throughput::Workload};
use pipeline_rl::simcluster::{SimCfg, Simulator};

fn main() {
    let w = Workload::paper_a4();

    benchkit::section("Fig 9 — RL throughput vs max lag g_max (N=128, B=128)");
    let budgets = vec![1, 2, 4, 8, 16, 32, 64, 96, 133, 192, 256, 384, 512];
    let grid: Vec<usize> = (4..=512).step_by(4).collect();
    let pipe = search::search_pipeline_configs(&w, &budgets, &grid);
    let conv = search::conventional_curve(&w, &budgets);
    let rows: Vec<Vec<String>> = pipe
        .iter()
        .zip(&conv)
        .map(|((budget, best), c)| {
            let (r, ih) = match best {
                Some(p) => (benchkit::f(p.r), format!("({},{})", p.i, p.h)),
                None => ("-".into(), "-".into()),
            };
            vec![
                budget.to_string(),
                r,
                ih,
                benchkit::f(c.r),
                best.map(|p| benchkit::f(p.r / c.r)).unwrap_or_default(),
            ]
        })
        .collect();
    benchkit::table(
        &["g_max", "r_pipeline", "(I,H)", "r_conv", "speedup"],
        &rows,
    );

    benchkit::section("Appendix A.4 — case study");
    let cs = search::case_study(&w);
    println!(
        "pipeline : r_gen {:.2} r_train {:.2} r {:.2}  (H={} I={} g_max={})",
        cs.pipe.r_gen, cs.pipe.r_train, cs.pipe.r, cs.pipe.h, cs.pipe.i, cs.pipe.lag_steps
    );
    println!(
        "convent. : r_gen {:.2} r_train {:.2} r {:.2}  (G={})",
        cs.conv.r_gen, cs.conv.r_train, cs.conv.r, cs.conv.g
    );
    println!("speedup  : {:.2}x  (paper: 1.57x at g_max ~ 133)", cs.speedup);

    benchkit::section("Fig 3b — effectiveness/throughput frontiers");
    let (pipe_pts, conv_pts) = search::pareto_sweep(&w);
    let rows: Vec<Vec<String>> = pipe_pts
        .iter()
        .map(|(e, r)| vec!["pipeline".into(), benchkit::f3(*e), benchkit::f(*r)])
        .chain(
            conv_pts
                .iter()
                .map(|(e, r)| vec!["conventional".into(), benchkit::f3(*e), benchkit::f(*r)]),
        )
        .collect();
    benchkit::table(&["method", "effectiveness proxy", "throughput"], &rows);

    benchkit::section("cross-check: analytic model vs discrete-event simulator");
    // scaled-down setup the simulator can run quickly
    let (n, i, h, b, l) = (32usize, 12usize, 96usize, 64usize, 256usize);
    let mut sw = Workload::paper_a4();
    sw.n = n;
    sw.b = b;
    sw.l_max = l;
    let analytic = pipeline_rl::perfmodel::pipeline(&sw, i, h);
    let mut cfg = SimCfg::pipeline(n, i, h, b, l);
    cfg.rl_steps = 48;
    let sim = Simulator::new(cfg).run();
    println!(
        "pipeline N={n} I={i} H={h}: analytic r = {:.2}, simulated r = {:.2} tokens/flash ({:+.1}%)",
        analytic.r,
        sim.throughput,
        100.0 * (sim.throughput - analytic.r) / analytic.r
    );
}
