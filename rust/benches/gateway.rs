//! Serving-gateway benchmarks — appended machine-readably to
//! BENCH_gateway.json (see benchkit docs). Entirely device-free: the
//! gateway schedules a [`SimService`] (deterministic hash tokens, real
//! paged-allocator accounting), so the numbers replay bit-for-bit.
//!
//! * QoS under open-loop load: interactive admission-to-first-token
//!   (p50/p99 in gateway ticks) and batch throughput across burst
//!   multipliers 1x/4x/8x, preemption on — the SLO table the acceptance
//!   test (tests/gateway.rs) asserts one row of;
//! * the same 8x flash crowd with preemption *off* — what the
//!   latency-sensitive eviction path is worth;
//! * per-tick scheduling overhead: a saturated `Gateway<SimService>`
//!   step vs the bare service step (the front door's bookkeeping cost).
//!
//! `cargo bench --bench gateway`

use pipeline_rl::benchkit::{self, f, time};
use pipeline_rl::config::GatewayConfig;
use pipeline_rl::data::task::{Problem, TaskKind};
use pipeline_rl::engine::{CompletionRequest, GenerationService};
use pipeline_rl::gateway::{Gateway, SimService};
use pipeline_rl::simcluster::{due_at, poisson_trace, ArrivalCfg};

const SEED: u64 = 0x6a7e_bec4;
const SLOTS: usize = 8;
const MAX_NEW: usize = 16;

fn problem(id: u64) -> Problem {
    Problem {
        kind: TaskKind::Add,
        prompt: format!("p{id}"),
        answer: String::new(),
        trace: String::new(),
        id,
    }
}

fn batch_req(id: u64) -> CompletionRequest {
    CompletionRequest::rollout(problem(id), vec![2, 3, 4, 5], id)
}

fn inter_req(id: u64, tenant: u64) -> CompletionRequest {
    CompletionRequest::interactive(problem(id), vec![2, 3, 4, 5], id, tenant)
}

struct Summary {
    arrivals: usize,
    p50_att: u64,
    p99_att: u64,
    preemptions: u64,
    finished_batch: u64,
    horizon: u64,
    ticks: u64,
}

fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).ceil() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// The acceptance scenario as a measurement: open-loop interactive
/// arrivals against a batch-saturated gateway, run to quiescence.
fn run_scenario(burst_mult: f64, preempt: bool) -> Summary {
    let mut cfg = GatewayConfig::default();
    cfg.preempt = preempt;
    let mut gw = Gateway::new(SimService::new(SLOTS, 64, 4, MAX_NEW, SEED), cfg);
    // short interactive turns, chosen a priori from the sim's
    // deterministic length rule
    let mut inter_pids = (10_000u64..).filter(|p| SimService::target_len(SEED, *p, MAX_NEW) <= 5);
    let arrivals = ArrivalCfg {
        rate: 0.06,
        horizon: 600,
        tenants: 4,
        burst_every: 150,
        burst_len: 30,
        burst_mult,
    };
    let trace = poisson_trace(&arrivals, SEED);
    let mut cursor = 0usize;
    let mut tickets = Vec::new();
    let mut next_batch = 100_000u64;
    for tick in 0..arrivals.horizon {
        for a in due_at(&trace, &mut cursor, tick) {
            let pid = inter_pids.next().expect("infinite ids");
            tickets.push(gw.submit(inter_req(pid, a.tenant)).expect("admitting"));
        }
        loop {
            let st = gw.stats();
            if (st.submitted_batch - st.finished_batch - st.shed_batch) >= 12 {
                break;
            }
            gw.submit(batch_req(next_batch)).expect("admitting");
            next_batch += 1;
        }
        gw.step().expect("step");
    }
    while gw.load() > 0 {
        gw.step().expect("drain step");
        assert!(gw.tick() < 20_000, "drain did not quiesce");
    }
    let mut att: Vec<u64> = tickets
        .iter()
        .filter_map(|&tid| {
            let t = gw.ticket(tid)?;
            let first = gw.svc().first_token_step(t.engine_seq?)?;
            Some(first - t.arrived_tick)
        })
        .collect();
    att.sort_unstable();
    let st = *gw.stats();
    Summary {
        arrivals: tickets.len(),
        p50_att: percentile(&att, 0.50),
        p99_att: percentile(&att, 0.99),
        preemptions: st.qos_preemptions,
        finished_batch: st.finished_batch,
        horizon: arrivals.horizon,
        ticks: gw.tick(),
    }
}

fn main() {
    benchkit::json_begin("gateway");

    benchkit::section("gateway — QoS under open-loop load (ticks)");
    {
        let mut rows = Vec::new();
        for &(mult, preempt) in &[(1.0, true), (4.0, true), (8.0, true), (8.0, false)] {
            let s = run_scenario(mult, preempt);
            let batch_tput = s.finished_batch as f64 / s.ticks as f64;
            rows.push(vec![
                format!("{mult}x"),
                if preempt { "on" } else { "off" }.to_string(),
                s.arrivals.to_string(),
                s.p50_att.to_string(),
                s.p99_att.to_string(),
                s.preemptions.to_string(),
                f(batch_tput),
            ]);
            if (mult - 8.0).abs() < f64::EPSILON && preempt {
                benchkit::json_note("p99_att_burst8_ticks", s.p99_att as f64);
                benchkit::json_note("p50_att_burst8_ticks", s.p50_att as f64);
                benchkit::json_note("qos_preemptions_burst8", s.preemptions as f64);
                benchkit::json_note("batch_throughput_burst8", batch_tput);
                benchkit::json_note("open_loop_horizon_ticks", s.horizon as f64);
            }
            if (mult - 8.0).abs() < f64::EPSILON && !preempt {
                benchkit::json_note("p99_att_burst8_nopreempt_ticks", s.p99_att as f64);
            }
        }
        benchkit::table(
            &["burst", "preempt", "arrivals", "p50 att", "p99 att", "preempts", "batch/tick"],
            &rows,
        );
    }

    benchkit::section("gateway — per-tick scheduling overhead");
    {
        // saturated steady state: refill one batch request per tick so
        // admission work happens every step in both setups
        let mut bare = SimService::new(SLOTS, 64, 4, MAX_NEW, SEED);
        let mut id = 1u64;
        for _ in 0..SLOTS {
            bare.submit(batch_req(id)).unwrap();
            id += 1;
        }
        let r0 = time("sim_step_saturated", 200, 3000, || {
            bare.submit(batch_req(id)).unwrap();
            id += 1;
            let _ = bare.step().unwrap();
        });
        let mut gw = Gateway::new(
            SimService::new(SLOTS, 64, 4, MAX_NEW, SEED),
            GatewayConfig::default(),
        );
        let mut gid = 1u64;
        for _ in 0..SLOTS {
            gw.submit(batch_req(gid)).unwrap();
            gid += 1;
        }
        let r1 = time("gateway_step_saturated", 200, 3000, || {
            gw.submit(batch_req(gid)).unwrap();
            gid += 1;
            let _ = gw.step().unwrap();
        });
        benchkit::json_note("sim_step_ms", r0.mean_ms);
        benchkit::json_note("gateway_step_ms", r1.mean_ms);
        benchkit::json_note("gateway_overhead_ms", (r1.mean_ms - r0.mean_ms).max(0.0));
    }

    benchkit::json_end();
}
