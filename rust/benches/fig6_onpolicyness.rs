//! Fig 6 (+ Fig 10's instability probe) — max token lag and Effective
//! Sample Size during training, PipelineRL vs Conventional G ∈ {2, 8}.
//!
//! Expected shape (paper): PipelineRL's *max* lag exceeds the
//! conventional baselines (mixed-policy sequences span many versions),
//! yet its ESS stays near the small-G baselines; large G degrades ESS —
//! taken to the extreme (G=64 in the paper, Fig 10) training diverges.
//!
//! `cargo bench --bench fig6_onpolicyness`

use pipeline_rl::benchkit;
use pipeline_rl::config::{Mode, RunConfig};
use pipeline_rl::coordinator;
use pipeline_rl::data::task::TaskKind;
use pipeline_rl::metrics::MetricsHub;
use pipeline_rl::runtime::Runtime;
use pipeline_rl::util::logging::{self, Level};

fn main() -> anyhow::Result<()> {
    logging::set_level(Level::Warn);
    benchkit::section("Fig 6 — max lag + ESS during training (tiny, 24 steps)");

    let mut base = RunConfig::default();
    base.variant = "tiny".into();
    base.rl_steps = 24;
    base.sft_steps = 60;
    base.group_size = 4;
    base.max_new_tokens = 24;
    base.task.kinds = vec![TaskKind::Copy, TaskKind::Add];
    base.task.max_operand = 20;
    base.log_every = 0;
    base.seed = 13;

    let warm = {
        let mut rt = Runtime::new()?;
        let hub = MetricsHub::new();
        coordinator::warmup::run_sft(&mut rt, &base, &hub)?
    };

    let mut summary_rows = Vec::new();
    // periodic k=4 probes the middle of the dial: overlap like pipeline,
    // publish cadence like a small conventional G
    for mode in [
        Mode::Pipeline,
        Mode::Periodic { k: 4 },
        Mode::Conventional { g: 2 },
        Mode::Conventional { g: 8 },
    ] {
        let mut cfg = base.clone();
        cfg.mode = mode;
        let s = coordinator::run(cfg.clone(), Some(warm.clone()))?;
        let lag = s.report.series("train/max_lag").cloned().unwrap_or_default();
        let ess = s.report.series("train/ess").cloned().unwrap_or_default();
        println!("\n-- mode {} --", cfg.mode.name());
        benchkit::series(
            "Fig 6a max token lag (optimizer steps)",
            &lag.points.iter().map(|p| p.x).collect::<Vec<_>>(),
            &lag.points.iter().map(|p| p.value).collect::<Vec<_>>(),
            8,
        );
        benchkit::series(
            "Fig 6b ESS",
            &ess.points.iter().map(|p| p.x).collect::<Vec<_>>(),
            &ess.points.iter().map(|p| p.value).collect::<Vec<_>>(),
            8,
        );
        summary_rows.push(vec![
            cfg.mode.name(),
            format!("{:.0}", lag.values().iter().cloned().fold(0.0, f64::max)),
            benchkit::f3(ess.tail_mean(8)),
            benchkit::f3(
                s.report
                    .series("train/mean_kl")
                    .map(|k| k.tail_mean(8))
                    .unwrap_or(f64::NAN),
            ),
            benchkit::f3(
                s.report
                    .series("train/clip_frac")
                    .map(|k| k.tail_mean(8))
                    .unwrap_or(f64::NAN),
            ),
        ]);
    }
    println!();
    benchkit::table(
        &["mode", "max lag", "ESS (tail)", "KL (tail)", "clip frac"],
        &summary_rows,
    );
    println!(
        "\nshape check (paper Fig 6): pipeline max-lag > conventional, but its\n\
         ESS tracks the small-G baseline; ESS decays as G grows (Fig 10's\n\
         G=64 divergence is this decay taken to destruction)."
    );
    Ok(())
}
