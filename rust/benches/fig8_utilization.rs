//! Fig 8 — H100 utilization U(h) vs matmul batch dimension.
//!
//! Prints the calibrated analytic curve (raw + padded, with the
//! power-of-two divisibility bumps the paper observed) and the
//! small-batch x/U(x) analysis that formally explains conventional RL's
//! inefficiency (Appendix A.2).
//!
//! `cargo bench --bench fig8_utilization`

use pipeline_rl::benchkit;
use pipeline_rl::perfmodel::AccelModel;

fn main() {
    let m = AccelModel::h100();

    benchkit::section("Fig 8 — utilization U(h) (calibrated model)");
    let hs: Vec<usize> = vec![
        1, 2, 4, 8, 12, 16, 24, 32, 48, 64, 96, 120, 127, 128, 160, 192,
        256, 320, 384, 448, 512, 768, 1024, 2048, 4096,
    ];
    let rows: Vec<Vec<String>> = m
        .table(&hs)
        .into_iter()
        .map(|(h, raw, pad)| {
            vec![
                h.to_string(),
                benchkit::f3(raw),
                benchkit::f3(pad),
                benchkit::f3(h as f64 / raw.max(1e-12)),
            ]
        })
        .collect();
    benchkit::table(&["h", "U_raw(h)", "U_padded(h)", "h/U(h) [flashes/step]"], &rows);

    benchkit::section("Appendix A.2 — why small per-GPU batches waste GPUs");
    println!(
        "h/U(h) is nearly constant for small h (each decode step costs the\n\
         same wall time whether the GPU holds 4 or 16 sequences):"
    );
    for h in [2usize, 4, 8, 16, 32] {
        println!("  h = {h:>3}: h/U(h) = {:.1} flashes", h as f64 / m.u_raw(h));
    }
    println!(
        "\ncalibration anchors: U(192) = {:.4} (paper A.4: r_gen = U(192)*44 = 16.9)",
        m.u_raw(192)
    );
}
