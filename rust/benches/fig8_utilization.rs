//! Fig 8 — H100 utilization U(h) vs matmul batch dimension.
//!
//! Prints the calibrated analytic curve (raw + padded, with the
//! power-of-two divisibility bumps the paper observed) and the
//! small-batch x/U(x) analysis that formally explains conventional RL's
//! inefficiency (Appendix A.2).
//!
//! `cargo bench --bench fig8_utilization`

use pipeline_rl::benchkit;
use pipeline_rl::perfmodel::AccelModel;

/// Engine-gated addendum: measure the real decode-step breakdown so the
/// analytic utilization curve can be compared against what the hot path
/// actually spends on staging vs compute vs readback (the before/after
/// evidence for the device-resident decode refactor).
fn measured_breakdown() -> anyhow::Result<()> {
    use pipeline_rl::data::task::TaskGen;
    use pipeline_rl::engine::{Engine, EngineCfg};
    use pipeline_rl::model::Tokenizer;
    use pipeline_rl::runtime::Runtime;
    use pipeline_rl::util::Rng;

    let mut rt = Runtime::new()?;
    let params = rt.init_params("tiny", 1)?;
    let mut cfg = EngineCfg::new("tiny");
    cfg.max_new_tokens = usize::MAX / 2;
    let mut eng = Engine::new(&mut rt, cfg, &params, 0, Rng::new(5))?;
    eng.set_weights(1, &params)?;
    let gen = TaskGen::curriculum_small();
    let tk = Tokenizer::new();
    for i in 0..eng.n_slots() {
        let p = gen.problem(i as u64);
        let toks = tk.encode(&p.prompt).unwrap();
        eng.add_request(p, toks, i as u64);
    }
    for _ in 0..32 {
        eng.step()?;
    }
    let s = &eng.stats;
    let steps = s.steps.max(1);
    println!(
        "measured tiny decode, {} steps: stage {:.0}us execute {:.0}us readback {:.0}us \
         per step; kv restages {} (device-resident: {})",
        steps,
        s.stage_us as f64 / steps as f64,
        s.execute_us as f64 / steps as f64,
        s.readback_us as f64 / steps as f64,
        s.kv_restages,
        eng.kv_on_device(),
    );
    Ok(())
}

fn main() {
    let m = AccelModel::h100();

    benchkit::section("Fig 8 — utilization U(h) (calibrated model)");
    let hs: Vec<usize> = vec![
        1, 2, 4, 8, 12, 16, 24, 32, 48, 64, 96, 120, 127, 128, 160, 192,
        256, 320, 384, 448, 512, 768, 1024, 2048, 4096,
    ];
    let rows: Vec<Vec<String>> = m
        .table(&hs)
        .into_iter()
        .map(|(h, raw, pad)| {
            vec![
                h.to_string(),
                benchkit::f3(raw),
                benchkit::f3(pad),
                benchkit::f3(h as f64 / raw.max(1e-12)),
            ]
        })
        .collect();
    benchkit::table(&["h", "U_raw(h)", "U_padded(h)", "h/U(h) [flashes/step]"], &rows);

    benchkit::section("Appendix A.2 — why small per-GPU batches waste GPUs");
    println!(
        "h/U(h) is nearly constant for small h (each decode step costs the\n\
         same wall time whether the GPU holds 4 or 16 sequences):"
    );
    for h in [2usize, 4, 8, 16, 32] {
        println!("  h = {h:>3}: h/U(h) = {:.1} flashes", h as f64 / m.u_raw(h));
    }
    println!(
        "\ncalibration anchors: U(192) = {:.4} (paper A.4: r_gen = U(192)*44 = 16.9)",
        m.u_raw(192)
    );

    benchkit::section("measured decode-step breakdown (engine-gated)");
    if pipeline_rl::runtime::runtime_available() {
        if let Err(e) = measured_breakdown() {
            eprintln!("measured breakdown failed: {e:#}");
        }
    } else {
        eprintln!("SKIP measured breakdown: PJRT runtime / AOT artifacts unavailable");
    }
}
