//! Hot-path micro-benchmarks (§Perf) — the numbers tracked in
//! ROADMAP.md §Perf before/after each optimization, and appended
//! machine-readably to BENCH_hotpath.json (see benchkit docs).
//!
//! * engine decode step (per variant): the request-path inner loop
//! * decode steady state: KV device-resident, arena-staged inputs,
//!   selective readback — with the EngineStats stage/execute/readback
//!   breakdown
//! * trainer optimizer step (per variant)
//! * weight swap: eager (decode stalls for the transfer) vs overlapped
//!   (shadow staging between steps + zero-stall commit)
//! * chunked prompt ingestion: dispatches-to-first-sample and wall time
//!   at `prefill_chunk` 1 vs W (timed section needs the runtime; the
//!   golden-shadow dispatch counts below run device-free)
//! * packer throughput, broker round-trip, RNG fill
//!
//! `cargo bench --bench hotpath`

use pipeline_rl::benchkit::{self, time};
use pipeline_rl::broker::{topic, Policy};
use pipeline_rl::coordinator::Packer;
use pipeline_rl::data::task::TaskGen;
use pipeline_rl::engine::{Engine, EngineCfg};
use pipeline_rl::model::Tokenizer;
use pipeline_rl::rl::{FinishReason, Rollout};
use pipeline_rl::runtime::{self, HostTensor, Runtime};
use pipeline_rl::util::logging::{self, Level};
use pipeline_rl::util::timer::{Stats, Stopwatch};
use pipeline_rl::util::Rng;
use std::time::Duration;

fn saturated_engine(rt: &mut Runtime, variant: &str) -> anyhow::Result<Engine> {
    let params = rt.init_params(variant, 1)?;
    let mut cfg = EngineCfg::new(variant);
    cfg.max_new_tokens = usize::MAX / 2; // keep slots busy forever
    let mut eng = Engine::new(rt, cfg, &params, 0, Rng::new(2))?;
    eng.set_weights(1, &params)?;
    let gen = TaskGen::curriculum_small();
    let tk = Tokenizer::new();
    for i in 0..eng.n_slots() {
        let p = gen.problem(i as u64);
        let toks = tk.encode(&p.prompt).unwrap();
        eng.add_request(p, toks, i as u64);
    }
    Ok(eng)
}

fn engine_benches() -> anyhow::Result<()> {
    benchkit::section("L3 hot paths — engine decode step");
    for variant in ["tiny", "small", "base"] {
        let mut rt = Runtime::new()?;
        let mut eng = saturated_engine(&mut rt, variant)?;
        let slots = eng.n_slots();
        let v = rt.manifest.variant(variant)?.clone();
        let r = time(
            &format!("decode step {variant} (B={} slots, full)", slots),
            3,
            20,
            || {
                eng.step().unwrap();
            },
        );
        let tokens_per_s = slots as f64 / (r.mean_ms / 1e3);
        benchkit::json_note(&format!("decode step {variant}/tokens_per_s"), tokens_per_s);
        println!(
            "    -> {:.0} tokens/s at batch {} (KV {:.1} MB, device-resident: {})",
            tokens_per_s,
            slots,
            v.kv_numel() as f64 * 4.0 / 1e6,
            eng.kv_on_device(),
        );
    }

    benchkit::section("L3 hot paths — decode steady state (breakdown)");
    {
        let mut rt = Runtime::new()?;
        let mut eng = saturated_engine(&mut rt, "base")?;
        // warm in: admit + first KV staging happen off the measurement
        for _ in 0..3 {
            eng.step()?;
        }
        let s0 = eng.stats.clone();
        let r = time("decode steady state base (KV resident)", 0, 32, || {
            eng.step().unwrap();
        });
        let s1 = eng.stats.clone();
        let steps = (s1.steps - s0.steps).max(1);
        let stage = (s1.stage_us - s0.stage_us) as f64 / steps as f64;
        let exec = (s1.execute_us - s0.execute_us) as f64 / steps as f64;
        let read = (s1.readback_us - s0.readback_us) as f64 / steps as f64;
        println!(
            "    -> per step: stage {stage:.0}us execute {exec:.0}us readback {read:.0}us \
             (kv restages {} over {} steps)",
            s1.kv_restages - s0.kv_restages,
            steps,
        );
        benchkit::json_note("decode steady state/stage_us", stage);
        benchkit::json_note("decode steady state/execute_us", exec);
        benchkit::json_note("decode steady state/readback_us", read);
        benchkit::json_note(
            "decode steady state/kv_restages",
            (s1.kv_restages - s0.kv_restages) as f64,
        );
        benchkit::json_note(
            "decode steady state/tokens_per_s",
            eng.n_slots() as f64 / (r.mean_ms / 1e3),
        );
    }

    benchkit::section("L3 hot paths — trainer optimizer step");
    for variant in ["tiny", "small"] {
        let mut rt = Runtime::new()?;
        let v = rt.manifest.variant(variant)?.clone();
        let graph = rt.graph(variant, "train")?;
        let params = rt.init_params(variant, 1)?;
        let m = rt.zero_opt_state(variant)?;
        let vv = rt.zero_opt_state(variant)?;
        let (b, t) = (v.train_batch, v.seq_len);
        let p = v.params.len();
        let mk_inputs = || {
            let mut inputs: Vec<HostTensor> = Vec::with_capacity(3 * p + 12);
            inputs.extend(params.iter().cloned());
            inputs.extend(m.iter().cloned());
            inputs.extend(vv.iter().cloned());
            inputs.push(HostTensor::scalar_f32(1.0));
            inputs.push(HostTensor::zeros_i32(&[b, t]));
            inputs.push(HostTensor::zeros_i32(&[b, t]));
            inputs.push(HostTensor::zeros_i32(&[b, t]));
            inputs.push(HostTensor::zeros_f32(&[b, t]));
            inputs.push(HostTensor::zeros_f32(&[b, t]));
            inputs.push(HostTensor::zeros_f32(&[b, t]));
            inputs.push(HostTensor::zeros_f32(&[b, t]));
            inputs.push(HostTensor::scalar_f32(1e-3));
            inputs.push(HostTensor::scalar_f32(5.0));
            inputs.push(HostTensor::scalar_f32(0.0));
            inputs.push(HostTensor::scalar_f32(0.0));
            inputs
        };
        let inputs = mk_inputs();
        let r = time(
            &format!("train step {variant} ([{b}x{t}], {:.2}M params)", v.n_params as f64 / 1e6),
            2,
            10,
            || {
                graph.run_host(&inputs).unwrap();
            },
        );
        let toks_per_s = (b * t) as f64 / (r.mean_ms / 1e3);
        println!("    -> {toks_per_s:.0} padded tokens/s");
    }

    benchkit::section("L3 hot paths — in-flight weight swap (eager stall)");
    for variant in ["tiny", "base"] {
        let mut rt = Runtime::new()?;
        let params = rt.init_params(variant, 1)?;
        let cfg = EngineCfg::new(variant);
        let mut eng = Engine::new(&mut rt, cfg, &params, 0, Rng::new(2))?;
        let mut ver = 1u64;
        let nbytes: usize = params.iter().map(|t| t.nbytes()).sum();
        let r = time(
            &format!("set_weights {variant} ({:.2} MB)", nbytes as f64 / 1e6),
            2,
            20,
            || {
                ver += 1;
                eng.set_weights(ver, &params).unwrap();
            },
        );
        println!(
            "    -> {:.1} MB/s transfer-equivalent, stall recorded {} us total",
            nbytes as f64 / 1e6 / (r.mean_ms / 1e3),
            eng.stats.weight_stall_us,
        );
    }

    benchkit::section("L3 hot paths — chunked prompt ingestion");
    {
        let mut rt = Runtime::new()?;
        let compiled_w = rt.manifest.variant("tiny")?.prefill_chunk;
        let prompt_len = 48usize; // stream = 49 positions to first sample
        for w in [1usize, 8] {
            if w > 1 && compiled_w < w {
                eprintln!(
                    "SKIP chunked ingestion at W={w}: artifacts compiled \
                     without prefill_chunk graphs (width {compiled_w})"
                );
                continue;
            }
            let params = rt.init_params("tiny", 1)?;
            let mut cfg = EngineCfg::new("tiny");
            cfg.max_new_tokens = usize::MAX / 2;
            cfg.prefill_chunk = w;
            let mut eng = Engine::new(&mut rt, cfg, &params, 0, Rng::new(2))?;
            let gen = TaskGen::curriculum_small();
            for i in 0..eng.n_slots() {
                let p = gen.problem(i as u64);
                let toks: Vec<i32> = (0..prompt_len).map(|t| 3 + (t % 40) as i32).collect();
                eng.add_request(p, toks, i as u64);
            }
            let sw = Stopwatch::new();
            let mut dispatches = 0u64;
            loop {
                let out = eng.step()?;
                dispatches += 1;
                if out.tokens_sampled > 0 || dispatches > 2 * (prompt_len as u64 + 2) {
                    break;
                }
            }
            let ms = sw.millis();
            println!(
                "chunked ingestion W={w}: {dispatches} dispatches to first sample \
                 ({ms:.2} ms, {} chunk dispatches, {} forced steps saved)",
                eng.stats.prefill_chunks, eng.stats.forced_steps_saved,
            );
            benchkit::json_note(
                &format!("chunked ingestion/dispatches_w{w}"),
                dispatches as f64,
            );
            benchkit::json_note(&format!("chunked ingestion/ms_w{w}"), ms);
            benchkit::json_note(
                &format!("chunked ingestion/forced_steps_saved_w{w}"),
                eng.stats.forced_steps_saved as f64,
            );
        }
    }

    benchkit::section("L3 hot paths — in-flight weight swap (overlapped)");
    {
        let mut rt = Runtime::new()?;
        let params = rt.init_params("base", 1)?;
        let mut eng = saturated_engine(&mut rt, "base")?;
        for _ in 0..2 {
            eng.step()?;
        }
        let mut ver = 1u64;
        let mut commit_stats = Stats::new();
        let swaps = 12u64;
        for _ in 0..swaps {
            ver += 1;
            eng.begin_weight_update(ver, params.len())?;
            // stage a couple of tensors between decode steps, like the actor
            let mut i = 0usize;
            while !eng.weight_update_ready() {
                for _ in 0..2 {
                    if i < params.len() {
                        eng.stage_weight_tensor(&params[i]).unwrap();
                        i += 1;
                    }
                }
                eng.step()?;
            }
            let sw = Stopwatch::new();
            eng.commit_weights()?.expect("staged set commits");
            commit_stats.push(sw.millis());
        }
        println!(
            "weight swap overlapped (base): commit {:.4} ms mean (±{:.4}, n={}), \
             decode stall from overlapped swaps: 0 us by construction \
             (stage_us interleaved: {} us over {} swaps)",
            commit_stats.mean(),
            commit_stats.std(),
            commit_stats.n,
            eng.stats.weight_stage_us,
            swaps,
        );
        benchkit::json_note("weight swap overlapped/commit_ms", commit_stats.mean());
        benchkit::json_note(
            "weight swap overlapped/stage_us_total",
            eng.stats.weight_stage_us as f64,
        );
        benchkit::json_note(
            "weight swap overlapped/overlapped_commits",
            eng.stats.overlapped_commits as f64,
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    logging::set_level(Level::Warn);
    benchkit::json_begin("hotpath");

    if runtime::runtime_available() {
        engine_benches()?;
    } else {
        eprintln!(
            "SKIP engine/trainer hot-path benches: PJRT runtime / AOT artifacts \
             unavailable (see tier1.sh); running substrate benches only"
        );
    }

    benchkit::section("substrate micro-benchmarks");
    // packer
    let mk_rollout = |n: usize| Rollout {
        seq_id: 0,
        problem_id: 1,
        group_id: 1,
        actor_id: 0,
        prompt_tokens: vec![1; 8],
        gen_tokens: vec![5; n],
        behavior_lp: vec![-0.5; n],
        token_version: vec![3; n],
        reward: 1.0,
        finish: FinishReason::Eos,
        t_start: 0.0,
        t_end: 0.0,
    };
    let rollouts: Vec<Rollout> = (0..64).map(|i| mk_rollout(16 + i % 32)).collect();
    time("packer: pack 64 rollouts into [16x224]", 3, 50, || {
        let mut p = Packer::new(16, 224);
        for r in &rollouts {
            if !p.try_add(r, 1.0) {
                let _ = p.flush();
                let _ = p.try_add(r, 1.0);
            }
        }
        std::hint::black_box(p.flush());
    });

    // broker round-trip (capacity > burst: single-threaded bench must
    // not hit the Block backpressure path, which needs a live consumer)
    let (tx, rx) = topic::<u64>("bench", 16_384, Policy::Block);
    time("broker: 10k send+recv round-trips", 2, 20, || {
        for i in 0..10_000u64 {
            tx.send(i).unwrap();
        }
        for _ in 0..10_000 {
            rx.recv(Duration::from_secs(1)).unwrap();
        }
    });

    // chunked-prefill dispatch accounting over the device-free golden
    // shadow: prompt ingestion plus chaos re-seating (kills, forced
    // preemptions) billed at W = 1 vs W = 8 — the O(P/W) replay claim
    // as machine-readable counts, runnable without any runtime
    benchkit::section("chunked prefill — dispatch accounting (device-free)");
    {
        use pipeline_rl::testkit::golden::{GoldenCfg, GoldenPipeline, Perturbation};
        let pert = Perturbation::generate(7, 12, 4, 3);
        for w in [1usize, 8] {
            let mut cfg = GoldenCfg::new(0xbe9c_11);
            cfg.steps = 12;
            cfg.live_target = 8;
            cfg.prefill_chunk = w;
            let run = GoldenPipeline::run(&cfg, &pert).expect("golden shadow run");
            println!(
                "    prefill_chunk={w}: {} prefill dispatches, {} forced steps saved \
                 ({} re-seated)",
                run.stats.prefill_dispatches, run.stats.forced_steps_saved,
                run.stats.migrated + run.stats.preemptions,
            );
            benchkit::json_note(
                &format!("chunked prefill shadow/dispatches_w{w}"),
                run.stats.prefill_dispatches as f64,
            );
            benchkit::json_note(
                &format!("chunked prefill shadow/forced_steps_saved_w{w}"),
                run.stats.forced_steps_saved as f64,
            );
        }
    }

    // rng gumbel fill (decode-loop noise)
    let mut rng = Rng::new(3);
    let mut buf = vec![0.0f32; 16 * 64];
    time("rng: gumbel fill 16x64 (decode noise)", 10, 1000, || {
        rng.fill_gumbel(&mut buf);
        std::hint::black_box(&buf);
    });

    benchkit::json_end();
    Ok(())
}
