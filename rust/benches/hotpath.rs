//! Hot-path micro-benchmarks (§Perf) — the numbers tracked in
//! EXPERIMENTS.md §Perf before/after each optimization.
//!
//! * engine decode step (per variant): the request-path inner loop
//! * trainer optimizer step (per variant)
//! * weight swap (in-flight update cost at the engine)
//! * packer throughput, broker round-trip, RNG fill
//!
//! `cargo bench --bench hotpath`

use pipeline_rl::benchkit::{self, time};
use pipeline_rl::broker::{topic, Policy};
use pipeline_rl::coordinator::Packer;
use pipeline_rl::data::task::TaskGen;
use pipeline_rl::engine::{Engine, EngineCfg};
use pipeline_rl::model::Tokenizer;
use pipeline_rl::rl::{FinishReason, Rollout};
use pipeline_rl::runtime::{HostTensor, Runtime};
use pipeline_rl::util::logging::{self, Level};
use pipeline_rl::util::Rng;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    logging::set_level(Level::Warn);

    benchkit::section("L3 hot paths — engine decode step");
    for variant in ["tiny", "small", "base"] {
        let mut rt = Runtime::new()?;
        let params = rt.init_params(variant, 1)?;
        let mut cfg = EngineCfg::new(variant);
        cfg.max_new_tokens = usize::MAX / 2; // keep slots busy forever
        let mut eng = Engine::new(&mut rt, cfg, &params, 0, Rng::new(2))?;
        eng.set_weights(1, &params)?;
        let gen = TaskGen::curriculum_small();
        let tk = Tokenizer::new();
        let slots = eng.n_slots();
        for i in 0..slots {
            let p = gen.problem(i as u64);
            let toks = tk.encode(&p.prompt).unwrap();
            eng.add_request(p, toks, i as u64);
        }
        let v = rt.manifest.variant(variant)?.clone();
        let r = time(
            &format!("decode step {variant} (B={} slots, full)", slots),
            3,
            20,
            || {
                eng.step().unwrap();
            },
        );
        let tokens_per_s = slots as f64 / (r.mean_ms / 1e3);
        println!(
            "    -> {:.0} tokens/s at batch {} (KV {:.1} MB round-trip)",
            tokens_per_s,
            slots,
            v.kv_numel() as f64 * 4.0 / 1e6
        );
    }

    benchkit::section("L3 hot paths — trainer optimizer step");
    for variant in ["tiny", "small"] {
        let mut rt = Runtime::new()?;
        let v = rt.manifest.variant(variant)?.clone();
        let graph = rt.graph(variant, "train")?;
        let params = rt.init_params(variant, 1)?;
        let m = rt.zero_opt_state(variant)?;
        let vv = rt.zero_opt_state(variant)?;
        let (b, t) = (v.train_batch, v.seq_len);
        let p = v.params.len();
        let mk_inputs = || {
            let mut inputs: Vec<HostTensor> = Vec::with_capacity(3 * p + 12);
            inputs.extend(params.iter().cloned());
            inputs.extend(m.iter().cloned());
            inputs.extend(vv.iter().cloned());
            inputs.push(HostTensor::scalar_f32(1.0));
            inputs.push(HostTensor::zeros_i32(&[b, t]));
            inputs.push(HostTensor::zeros_i32(&[b, t]));
            inputs.push(HostTensor::zeros_i32(&[b, t]));
            inputs.push(HostTensor::zeros_f32(&[b, t]));
            inputs.push(HostTensor::zeros_f32(&[b, t]));
            inputs.push(HostTensor::zeros_f32(&[b, t]));
            inputs.push(HostTensor::zeros_f32(&[b, t]));
            inputs.push(HostTensor::scalar_f32(1e-3));
            inputs.push(HostTensor::scalar_f32(5.0));
            inputs.push(HostTensor::scalar_f32(0.0));
            inputs.push(HostTensor::scalar_f32(0.0));
            inputs
        };
        let inputs = mk_inputs();
        let r = time(
            &format!("train step {variant} ([{b}x{t}], {:.2}M params)", v.n_params as f64 / 1e6),
            2,
            10,
            || {
                graph.run_host(&inputs).unwrap();
            },
        );
        let toks_per_s = (b * t) as f64 / (r.mean_ms / 1e3);
        println!("    -> {toks_per_s:.0} padded tokens/s");
    }

    benchkit::section("L3 hot paths — in-flight weight swap");
    for variant in ["tiny", "base"] {
        let mut rt = Runtime::new()?;
        let params = rt.init_params(variant, 1)?;
        let cfg = EngineCfg::new(variant);
        let mut eng = Engine::new(&mut rt, cfg, &params, 0, Rng::new(2))?;
        let mut ver = 1u64;
        let nbytes: usize = params.iter().map(|t| t.nbytes()).sum();
        let r = time(
            &format!("set_weights {variant} ({:.2} MB)", nbytes as f64 / 1e6),
            2,
            20,
            || {
                ver += 1;
                eng.set_weights(ver, &params).unwrap();
            },
        );
        println!(
            "    -> {:.1} MB/s transfer-equivalent",
            nbytes as f64 / 1e6 / (r.mean_ms / 1e3)
        );
    }

    benchkit::section("substrate micro-benchmarks");
    // packer
    let mk_rollout = |n: usize| Rollout {
        seq_id: 0,
        problem_id: 1,
        group_id: 1,
        actor_id: 0,
        prompt_tokens: vec![1; 8],
        gen_tokens: vec![5; n],
        behavior_lp: vec![-0.5; n],
        token_version: vec![3; n],
        reward: 1.0,
        finish: FinishReason::Eos,
        t_start: 0.0,
        t_end: 0.0,
    };
    let rollouts: Vec<Rollout> = (0..64).map(|i| mk_rollout(16 + i % 32)).collect();
    time("packer: pack 64 rollouts into [16x224]", 3, 50, || {
        let mut p = Packer::new(16, 224);
        for r in &rollouts {
            if !p.try_add(r, 1.0) {
                let _ = p.flush();
                let _ = p.try_add(r, 1.0);
            }
        }
        std::hint::black_box(p.flush());
    });

    // broker round-trip (capacity > burst: single-threaded bench must
    // not hit the Block backpressure path, which needs a live consumer)
    let (tx, rx) = topic::<u64>("bench", 16_384, Policy::Block);
    time("broker: 10k send+recv round-trips", 2, 20, || {
        for i in 0..10_000u64 {
            tx.send(i).unwrap();
        }
        for _ in 0..10_000 {
            rx.recv(Duration::from_secs(1)).unwrap();
        }
    });

    // rng gumbel fill (decode-loop noise)
    let mut rng = Rng::new(3);
    let mut buf = vec![0.0f32; 16 * 64];
    time("rng: gumbel fill 16x64 (decode noise)", 10, 1000, || {
        rng.fill_gumbel(&mut buf);
        std::hint::black_box(&buf);
    });
    Ok(())
}
