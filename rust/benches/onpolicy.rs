//! Off-policyness-dial benchmarks — appended machine-readably to
//! BENCH_onpolicy.json (see benchkit docs). Entirely device-free.
//!
//! Three sweeps, one per layer of the dial:
//!
//! * **ESS vs lag** (Eq. 5/6): a synthetic lagged policy drifts away
//!   from the behavior logprobs — bias ∝ lag, noise ∝ √lag (the policy
//!   random-walks between published versions) — and the truncated-IS
//!   weights' effective sample size is measured at each depth. This is
//!   the ESS(lag) table every other section prices corrections with.
//! * **mode × correction learning curves**: pipeline / periodic(k) /
//!   conventional cadences simulated with and without IS correction.
//!   Uncorrected tokens pay the paper's bias discount 1/(1 + α·lag);
//!   corrected tokens are unbiased but pay the variance price instead —
//!   their effectiveness is exactly the ESS fraction at their lag. The
//!   headline artifact: the deepest lag each (mode, correction) pair
//!   sustains at equal learning-curve shape, which must be deeper for
//!   the corrected runs.
//! * **autoscaler freshness guards**: a replayed signal schedule with
//!   ramping lag, scored by a `max_lag_steps` guard vs an `ess_floor`
//!   guard — the ESS guard keeps scaling long past the raw step cap
//!   because the correction has already paid for the lag.
//!
//! `cargo bench --bench onpolicy`

use pipeline_rl::benchkit;
use pipeline_rl::perfmodel::learning::simulate;
use pipeline_rl::perfmodel::{conventional, search_pipeline_configs, LearnCfg, Workload};
use pipeline_rl::rl::{effective_sample_size, truncated_weights};
use pipeline_rl::sched::{AutoScaleCfg, AutoScaler, ScaleDecision, ScaleSignals};
use pipeline_rl::util::Rng;

const CLIP_C: f32 = 2.0;
const MAX_LAG: usize = 160;

/// Measured ESS of truncated-IS weights at one lag depth. Per-token
/// drift model: E[lp_pi - lp_mu] = -0.005·lag (systematic bias) with
/// std ≈ 0.087·√lag (version-to-version random walk), ~16k tokens.
fn ess_at_lag(lag: f64, rng: &mut Rng) -> f64 {
    const SEQS: usize = 128;
    const LEN: usize = 128;
    let l = lag as f32;
    let mut weights = Vec::with_capacity(SEQS * LEN);
    for _ in 0..SEQS {
        let lp_mu: Vec<f32> = (0..LEN).map(|_| -0.05 - 2.0 * rng.f32()).collect();
        let lp_pi: Vec<f32> = lp_mu
            .iter()
            .map(|&lp| {
                // Irwin-Hall(4) recentred: mean 0, std ~0.577
                let n = rng.f32() + rng.f32() + rng.f32() + rng.f32() - 2.0;
                lp - 0.005 * l + 0.15 * l.sqrt() * n
            })
            .collect();
        weights.extend(truncated_weights(&lp_pi, &lp_mu, CLIP_C));
    }
    effective_sample_size(&weights)
}

/// ESS(lag) lookup for 0..=MAX_LAG optimizer steps of lag.
fn ess_table(seed: u64) -> Vec<f64> {
    let mut rng = Rng::with_stream(seed, 0xe55);
    (0..=MAX_LAG).map(|l| ess_at_lag(l as f64, &mut rng)).collect()
}

fn ess_of(tab: &[f64], lag: f64) -> f64 {
    tab[(lag.round() as usize).min(MAX_LAG)]
}

/// The uncorrected per-token bias discount the learning model uses
/// (perfmodel::learning): 1/(1 + α·lag).
fn bias_discount(alpha: f64, lag: f64) -> f64 {
    1.0 / (1.0 + alpha * lag)
}

/// Mean of `bias_discount` over token lags Uniform(0..g) — the Fig 3a
/// pipeline ramp.
fn ramp_discount(alpha: f64, g: f64) -> f64 {
    if g > 0.0 {
        (1.0 + alpha * g).ln() / (alpha * g)
    } else {
        1.0
    }
}

fn main() {
    benchkit::json_begin("onpolicy");
    let seed = 0x0ff_d1a1u64; // the off-policyness dial
    let tab = ess_table(seed);

    benchkit::section("onpolicy — ESS vs lag (truncated IS, Eq. 5/6)");
    for &lag in &[0usize, 1, 2, 4, 8, 16, 32, 64, 128] {
        let ess = ess_of(&tab, lag as f64);
        println!("lag {lag:>3} steps -> ESS {ess:.3}");
        benchkit::json_note(&format!("ess/lag_{lag}"), ess);
    }

    benchkit::section("onpolicy — mode x correction learning-curve sweep");
    let w = Workload::paper_a4();
    let lc = LearnCfg::default();
    let a = lc.alpha;
    let grid: Vec<usize> = (4..=512).step_by(4).collect();
    let lag_budgets = [8usize, 16, 32, 64, 128];
    let k = 4usize; // periodic publish cadence

    // equal-shape criterion: a (mode, correction, g) point "sustains"
    // its lag when its final reward stays within 10% of the zero-lag
    // curve at the same sample count — shape, not wall-clock (reward per
    // optimizer step is independent of tokens/flash, which only scales
    // the time axis)
    let zero_lag = simulate(&w, &lc, 10.0, |_| 1.0).final_reward();
    let sustains = |final_reward: f64| final_reward >= 0.9 * zero_lag;

    let mut deepest = [[0usize; 2]; 3]; // [mode][corrected] -> max sustained g
    let modes = ["pipeline", "periodic_k4", "conventional"];
    for &g in &lag_budgets {
        let pipe = search_pipeline_configs(&w, &[g], &grid)[0]
            .1
            .expect("pipeline config within lag budget");
        let conv = conventional(&w, g);
        let gp = pipe.lag_steps as f64;

        for (mi, mode) in modes.iter().enumerate() {
            for corrected in [false, true] {
                // per-step effectiveness under this mode's token-lag
                // distribution: bias discount when uncorrected, ESS
                // fraction (unbiased, variance-priced) when corrected
                let tab_ref = &tab;
                let eff: Box<dyn Fn(usize) -> f64 + '_> = match (mi, corrected) {
                    // pipeline: lags mix uniformly over 0..g_max
                    (0, false) => Box::new(move |_| ramp_discount(a, gp)),
                    (0, true) => Box::new(move |_| ess_of(tab_ref, gp / 2.0)),
                    // periodic(k): the uniform ramp plus 0..k-1 steps of
                    // publish staleness cycling with the cadence
                    (1, false) => Box::new(move |s| ramp_discount(a, gp + (s % k) as f64)),
                    (1, true) => {
                        Box::new(move |s| ess_of(tab_ref, gp / 2.0 + (s % k) as f64))
                    }
                    // conventional: batch j of each RL step sits at lag j
                    (_, false) => Box::new(move |s| bias_discount(a, (s % g) as f64)),
                    (_, true) => Box::new(move |s| ess_of(tab_ref, (s % g) as f64)),
                };
                let r = if mi == 2 { conv.r } else { pipe.r };
                let curve = simulate(&w, &lc, r, &eff);
                let t_half = curve.time_to(0.5 * lc.r_max).unwrap_or(f64::NAN);
                let shape = curve.final_reward();
                let tag = if corrected { "truncated" } else { "none" };
                benchkit::json_note(
                    &format!("curve/{mode}/g{g}/{tag}/t_half_flashes"),
                    t_half,
                );
                benchkit::json_note(
                    &format!("curve/{mode}/g{g}/{tag}/final_reward"),
                    shape,
                );
                if sustains(shape) {
                    deepest[mi][corrected as usize] = g;
                }
            }
        }
    }
    for (mi, mode) in modes.iter().enumerate() {
        let [plain, corr] = deepest[mi];
        println!(
            "{mode}: deepest sustained lag — uncorrected {plain} steps, \
             truncated-IS {corr} steps"
        );
        benchkit::json_note(&format!("sustain/{mode}/none"), plain as f64);
        benchkit::json_note(&format!("sustain/{mode}/truncated"), corr as f64);
        assert!(
            corr >= plain,
            "{mode}: correction must never sustain less lag than none"
        );
    }

    benchkit::section("onpolicy — autoscaler freshness guards under ramping lag");
    {
        let mk_cfg = |max_lag_steps: f64, ess_floor: f64| AutoScaleCfg {
            enabled: true,
            backlog_per_actor: 1.0,
            supply_high_frac: 0.75,
            up_patience: 1,
            down_patience: 3,
            cooldown: 0,
            max_lag_steps,
            ess_floor,
            min_batch_fill: 0.0,
            eval_every_ms: 0,
        };
        // lag ramps 0 -> 158 optimizer steps over 80 evaluations while
        // backlog pressure stays on; each guard decides when to stop
        let replay = |cfg: AutoScaleCfg| -> (u64, f64) {
            let mut scaler = AutoScaler::new(cfg);
            let mut last_up_lag = 0.0;
            for i in 0..80u64 {
                let lag = i as f64 * 2.0;
                let sig = ScaleSignals {
                    backlog: 64,
                    supply_depth: 10,
                    supply_capacity: 256,
                    token_lag: lag,
                    ess: ess_of(&tab, lag),
                    batch_fill: 1.0,
                    pool: 4,
                };
                if scaler.decide(&sig) == ScaleDecision::Up {
                    last_up_lag = lag;
                }
            }
            (scaler.ups(), last_up_lag)
        };
        let (ups_lag, depth_lag) = replay(mk_cfg(4.0, 0.0));
        let (ups_ess, depth_ess) = replay(mk_cfg(0.0, 0.55));
        println!(
            "lag guard (cap 4): {ups_lag} scale-ups, last at lag {depth_lag}; \
             ESS guard (floor 0.55): {ups_ess} scale-ups, last at lag {depth_ess}"
        );
        benchkit::json_note("autoscale/ups_lag_guard", ups_lag as f64);
        benchkit::json_note("autoscale/last_up_lag_guard", depth_lag);
        benchkit::json_note("autoscale/ups_ess_guard", ups_ess as f64);
        benchkit::json_note("autoscale/last_up_ess_guard", depth_ess);
        assert!(
            ups_ess > ups_lag && depth_ess > depth_lag,
            "the ESS floor must admit scaling deeper into lag than the step cap"
        );
    }

    if let Some(p) = benchkit::json_end() {
        println!("results -> {}", p.display());
    }
}
