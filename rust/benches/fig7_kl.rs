//! Fig 7 — KL(mixed in-flight behavior policy ‖ on-policy checkpoint) as
//! a function of lag, with and without KV-cache recomputation, vs the
//! conventional fixed-lag policy. Shortened version of
//! `examples/kl_inflight.rs` (same library code).
//!
//! `cargo bench --bench fig7_kl`

use pipeline_rl::benchkit;
use pipeline_rl::config::RunConfig;
use pipeline_rl::coordinator::{self, klstudy::{replay_kl, Swap}};
use pipeline_rl::model::checkpoint::TrainState;
use pipeline_rl::runtime::HostTensor;
use pipeline_rl::util::logging::{self, Level};

fn main() -> anyhow::Result<()> {
    logging::set_level(Level::Warn);
    benchkit::section("Fig 7 — per-token KL vs lag (tiny, 12 checkpoints)");

    let steps = 12usize;
    let ckpt_dir = std::env::temp_dir().join("prl_fig7_ckpts");
    std::fs::create_dir_all(&ckpt_dir)?;
    let mut cfg = RunConfig::default();
    cfg.variant = "tiny".into();
    cfg.sft_steps = 40;
    cfg.rl_steps = steps;
    cfg.max_new_tokens = 24;
    cfg.checkpoint.every = 1;
    cfg.checkpoint.dir = Some(ckpt_dir.to_string_lossy().to_string());
    cfg.log_every = 0;
    cfg.seed = 7;
    coordinator::run(cfg.clone(), None)?;

    let load = |step: usize| -> anyhow::Result<Vec<HostTensor>> {
        let p = ckpt_dir.join(TrainState::file_name(step as u64));
        Ok(TrainState::load(&p)?.params)
    };

    let start = 1usize;
    let mut rows = Vec::new();
    for g in [1usize, 2, 4, 8] {
        if start + g > steps {
            break;
        }
        let stale = replay_kl(&cfg, &load, start, g, Swap::InFlight { recompute: false })?;
        let rec = replay_kl(&cfg, &load, start, g, Swap::InFlight { recompute: true })?;
        let conv = replay_kl(&cfg, &load, start, g, Swap::None)?;
        rows.push(vec![
            g.to_string(),
            format!("{stale:.5}"),
            format!("{rec:.5}"),
            format!("{conv:.5}"),
        ]);
    }
    benchkit::table(
        &["lag g", "pipeline stale-KV", "pipeline recompute", "conventional"],
        &rows,
    );
    println!(
        "\nshape check (paper Fig 7): conventional KL grows with lag; both\n\
         pipeline variants stay low; stale KV ~ recompute (the §5.1 design\n\
         choice to keep the cache)."
    );
    Ok(())
}
