//! KV-memory benchmarks — appended machine-readably to BENCH_kvmem.json
//! (see benchkit docs). Entirely device-free: the paged allocator and
//! the park/resume bookkeeping are host-side.
//!
//! * blocks saved by prefix sharing at G ∈ {4, 8, 16} — the admission
//!   headroom a GRPO group buys back (the dominant KV cost for long
//!   prompts is the prompt itself);
//! * preempt → resume round-trip cost: release + snapshot roundtrip +
//!   re-admission across generated-prefix lengths (the per-sequence
//!   price of shedding load under block pressure);
//! * coalesced vs serial replay count: replays needed to land N
//!   imported/parked sequences when slots free one at a time — the
//!   N-replay quadratic the admission window kills;
//! * device bytes held: dense per-slot tensor vs the paged block pool
//!   at a realistic mid-run occupancy — the HBM the `[kv] layout =
//!   "paged"` path actually gives back;
//! * replay dispatch rows: per-row replay vs the legacy full-batch
//!   rebuild — row-steps re-fed through the decode graph to land N
//!   imports next to resident sequences.
//!
//! `cargo bench --bench kvmem`

use pipeline_rl::benchkit::{self, time};
use pipeline_rl::data::task::TaskGen;
use pipeline_rl::engine::kvcache::{replay_window_open, BlockAllocator};
use pipeline_rl::engine::SeqState;
use pipeline_rl::sched::SeqSnapshot;

/// Replays needed to land `n` pending pos>0 sequences when one slot
/// frees per step (the mass-descale trickle): every step the window is
/// consulted; an open window seats everything the free slots hold and
/// costs one replay.
fn replay_rounds(n: usize, batch: usize, n_slots: usize) -> usize {
    let (mut waiting, mut free, mut rounds) = (n, 0usize, 0usize);
    let mut steps = 0;
    while waiting > 0 {
        steps += 1;
        assert!(steps < 10_000, "window starved");
        free = (free + 1).min(n_slots);
        if replay_window_open(waiting, free, batch, n_slots) {
            let seated = waiting.min(free);
            waiting -= seated;
            free -= seated;
            rounds += 1;
        }
    }
    rounds
}

fn main() {
    benchkit::json_begin("kvmem");

    benchkit::section("kvmem — blocks saved by prefix sharing");
    {
        let (prompt, bs, budget_per) = (96usize, 16usize, 128usize / 16);
        let mut rows = Vec::new();
        for &g in &[4usize, 8, 16] {
            let mut private = BlockAllocator::new(g * budget_per, bs);
            for i in 0..g {
                private.admit(i as u64, prompt).unwrap();
            }
            let mut shared = BlockAllocator::new(g * budget_per, bs);
            for i in 0..g {
                shared.admit_shared(i as u64, 1, prompt).unwrap();
            }
            let saved = shared.shared_saved_blocks();
            assert_eq!(private.held_blocks(), g * prompt.div_ceil(bs));
            assert_eq!(saved, (g - 1) * prompt.div_ceil(bs));
            benchkit::json_note(&format!("prefix_share/G={g}/blocks_private"),
                private.held_blocks() as f64);
            benchkit::json_note(&format!("prefix_share/G={g}/blocks_shared"),
                shared.held_blocks() as f64);
            benchkit::json_note(&format!("prefix_share/G={g}/blocks_saved"), saved as f64);
            rows.push(vec![
                g.to_string(),
                private.held_blocks().to_string(),
                shared.held_blocks().to_string(),
                saved.to_string(),
                format!("{:.1}%", 100.0 * saved as f64 / private.held_blocks() as f64),
            ]);
        }
        benchkit::table(
            &["G", "blocks private", "blocks shared", "saved", "saved %"],
            &rows,
        );
    }

    benchkit::section("kvmem — preempt -> resume round-trip cost");
    {
        let problem = TaskGen::curriculum_small().problem(5);
        for &gen_len in &[16usize, 256, 4096] {
            let mut seq = SeqState::new(
                7,
                (1u64 << 40) | 7,
                problem.clone(),
                vec![11; 15],
                1,
                gen_len + 8,
                0.0,
            );
            // fast-forward: prefill then gen_len sampled tokens
            for _ in 0..15 {
                seq.advance(0, 0.0, 1, -1, usize::MAX / 2);
            }
            for t in 0..gen_len as i32 {
                seq.advance(100 + t, -0.5, 1, -1, usize::MAX / 2);
            }
            let total = seq.total_len();
            let mut alloc = BlockAllocator::new(2 * total.div_ceil(16) + 4, 16);
            alloc.admit(7, total).unwrap();
            time(&format!("preempt+resume round-trip ({gen_len} gen tokens)"), 10, 200, || {
                // park: free the blocks, export through the snapshot path
                alloc.release(7).unwrap();
                let snap: SeqSnapshot = seq.to_snapshot([1, 2, 3, 4]);
                let parked = SeqState::from_snapshot(&snap, 7, problem.clone(), 0.0);
                // resume: re-admit and rebuild the state
                alloc.admit(7, parked.total_len()).unwrap();
                std::hint::black_box(parked);
            });
        }
    }

    benchkit::section("kvmem — coalesced vs serial replay count");
    {
        let (n, slots) = (32usize, 8usize);
        let mut rows = Vec::new();
        for &batch in &[1usize, 4, 8] {
            let rounds = replay_rounds(n, batch, slots);
            assert!(rounds <= n.div_ceil(batch).max(n.div_ceil(slots)));
            benchkit::json_note(&format!("replay_coalesce/batch={batch}/rounds"), rounds as f64);
            rows.push(vec![batch.to_string(), n.to_string(), rounds.to_string()]);
        }
        benchkit::table(&["replay_batch", "imports", "replay rounds"], &rows);
        println!(
            "(serial batch=1 pays one full-batch replay per import; the window \
             amortizes it to ceil(N/batch))"
        );
    }

    benchkit::section("kvmem — device bytes: dense per-slot tensor vs paged pool");
    {
        // TINY decode-graph dims (python/compile/configs.py): L=2 layers,
        // H=2 heads, hd=16, block_size=16, 6 blocks per row -> max_seq 96.
        // Dense bills every slot for max_seq tokens whether used or not;
        // paged bills only the blocks the allocator actually holds.
        let (l, h, hd, bs, nb_row, slots) = (2usize, 2usize, 16usize, 16usize, 6usize, 8usize);
        let tok_bytes = l * 2 * h * hd * 4; // f32 K+V across layers, per token
        let dense_bytes = slots * (bs * nb_row) * tok_bytes;
        // mid-run occupancy: a 4-member GRPO group on a 30-token shared
        // prompt plus four solo rows at varied fills
        let mut alloc = BlockAllocator::new(slots * nb_row, bs);
        for i in 0..4u64 {
            alloc.admit_shared(i, 1, 30).unwrap();
        }
        for (i, &total) in [34usize, 50, 66, 18].iter().enumerate() {
            alloc.admit(10 + i as u64, total).unwrap();
        }
        let paged_bytes = alloc.held_blocks() * bs * tok_bytes;
        let saved = 100.0 * (dense_bytes - paged_bytes) as f64 / dense_bytes as f64;
        benchkit::json_note("pool_bytes/dense", dense_bytes as f64);
        benchkit::json_note("pool_bytes/paged", paged_bytes as f64);
        benchkit::json_note("pool_bytes/saved_pct", saved);
        benchkit::table(
            &["layout", "device KV bytes", "vs dense"],
            &[
                vec!["dense".into(), dense_bytes.to_string(), "-".into()],
                vec![
                    "paged".into(),
                    paged_bytes.to_string(),
                    format!("-{saved:.1}%"),
                ],
            ],
        );
        println!(
            "(8 slots x 96-token rows; paged holds {} of {} pool blocks)",
            alloc.held_blocks(),
            slots * nb_row
        );
    }

    benchkit::section("kvmem — replay dispatch rows: per-row vs full-batch");
    {
        // Landing n imports (64-token prefix each) in one coalesced replay
        // while the other slots hold residents mid-generation: the legacy
        // full-batch rebuild re-feeds every active row at every prefix
        // position; per-row replay feeds only the re-admitted rows and
        // skips the residents (stats.replay_rows_skipped).
        let (slots, prefix) = (8usize, 64usize);
        let mut rows = Vec::new();
        for &n in &[1usize, 2, 4] {
            let per_row = prefix * n;
            let full_batch = prefix * slots;
            assert!(per_row <= full_batch);
            benchkit::json_note(
                &format!("replay_dispatch/imports={n}/row_steps_full_batch"),
                full_batch as f64,
            );
            benchkit::json_note(
                &format!("replay_dispatch/imports={n}/row_steps_per_row"),
                per_row as f64,
            );
            rows.push(vec![
                n.to_string(),
                (slots - n).to_string(),
                full_batch.to_string(),
                per_row.to_string(),
                format!("{:.1}%", 100.0 * (full_batch - per_row) as f64 / full_batch as f64),
            ]);
        }
        benchkit::table(
            &["imports", "residents", "row-steps full-batch", "row-steps per-row", "saved"],
            &rows,
        );
    }

    if let Some(p) = benchkit::json_end() {
        println!("results -> {}", p.display());
    }
}
