//! Fig 3a — token-lag structure: PipelineRL vs Conventional RL.
//!
//! Simulated at cluster scale (flash units): per-relative-position mean
//! token lag inside trained sequences. Expected shape (paper): for
//! PipelineRL the lag ramps *down* across the sequence (early tokens are
//! the most off-policy, recent tokens lag ≤ 1); doubling the actor pool
//! doubles the early-token lag; Conventional RL is flat within a batch.
//!
//! `cargo bench --bench fig3_lag`

use pipeline_rl::benchkit;
use pipeline_rl::simcluster::{SimCfg, Simulator};

fn run(cfg: SimCfg) -> Vec<f64> {
    Simulator::new(cfg).run().lag_by_relpos
}

fn main() {
    benchkit::section("Fig 3a — mean token lag by relative position (16 buckets)");

    let b = 64;
    let l = 128;
    let mut pipe_n = SimCfg::pipeline(24, 8, 48, b, l);
    pipe_n.rl_steps = 80;
    let mut pipe_2n = SimCfg::pipeline(40, 16, 48, b, l);
    pipe_2n.rl_steps = 80;
    let mut conv = SimCfg::conventional(24, 8, 48, b, l);
    conv.rl_steps = 80;

    let lag_n = run(pipe_n);
    let lag_2n = run(pipe_2n);
    let lag_conv = run(conv);

    let rows: Vec<Vec<String>> = (0..16)
        .map(|i| {
            vec![
                format!("{:.0}%", (i as f64 + 0.5) * 100.0 / 16.0),
                benchkit::f(lag_n[i]),
                benchkit::f(lag_2n[i]),
                benchkit::f(lag_conv[i]),
            ]
        })
        .collect();
    benchkit::table(
        &["seq position", "pipeline (I=8)", "pipeline (I=16)", "conventional G=8"],
        &rows,
    );

    let ratio = lag_2n[0] / lag_n[0].max(1e-9);
    println!(
        "\nearly-token lag ratio (2x actors / 1x actors): {ratio:.2} (paper: ~2x)"
    );
    println!(
        "pipeline lag ramp (first/last bucket): {:.1}x; conventional flat",
        lag_n[0] / lag_n[15].max(1e-9)
    );
}
