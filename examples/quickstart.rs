//! Quickstart: the whole stack in ~60 seconds on the tiny variant.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Runs a short SFT warmup (base-model stand-in), then a handful of
//! PipelineRL optimizer steps with in-flight weight updates, evaluates
//! the result on held-out problems, and prints what happened.

use pipeline_rl::config::RunConfig;
use pipeline_rl::coordinator::{self, eval};
use pipeline_rl::data::task::TaskKind;
use pipeline_rl::runtime::Runtime;
use pipeline_rl::util::logging::{self, Level};

fn main() -> anyhow::Result<()> {
    logging::set_level(Level::Info);
    let mut cfg = RunConfig::default();
    cfg.variant = "tiny".into();
    cfg.sft_steps = 40;
    cfg.rl_steps = 12;
    cfg.group_size = 4;
    cfg.max_new_tokens = 24;
    cfg.task.kinds = vec![TaskKind::Copy, TaskKind::Add];
    cfg.task.max_operand = 20;
    cfg.log_every = 4;

    println!("== PipelineRL quickstart (variant {}) ==", cfg.variant);
    let summary = coordinator::run(cfg.clone(), None)?;

    let mut rt = Runtime::new()?;
    let before = eval::evaluate(&mut rt, &cfg, &summary.initial_params, 40)?;
    let after = eval::evaluate(&mut rt, &cfg, &summary.final_params, 40)?;

    println!("\n== results ==");
    println!("wall time          : {:.1} s", summary.wall_seconds);
    println!(
        "samples trained    : {}",
        summary.report.counters["samples_trained"]
    );
    println!(
        "tokens generated   : {}",
        summary.report.counters["gen_tokens_sampled"]
    );
    println!(
        "in-flight updates  : {}",
        summary.report.counters.get("weight_updates_received").copied().unwrap_or(0.0)
    );
    let ess = summary.report.series("train/ess").unwrap();
    println!("final ESS          : {:.3}", ess.tail_mean(3));
    println!(
        "eval success       : {:.1}% -> {:.1}%  (held-out, greedy)",
        100.0 * before.success_rate(),
        100.0 * after.success_rate()
    );
    println!("\nSee examples/train_pipeline_rl.rs for the full experiment.");
    Ok(())
}
