//! Soak test: run the engine decode loop (and optionally train steps)
//! for a fixed duration and report RSS growth — guards against device
//! buffer / literal leaks in the PJRT hot path (we already fixed one
//! upstream leak in the xla crate's `execute`; see runtime/mod.rs).
//!
//! ```bash
//! cargo run --release --example soak -- --seconds 60 --train
//! ```

use pipeline_rl::data::task::TaskGen;
use pipeline_rl::engine::{Engine, EngineCfg};
use pipeline_rl::model::Tokenizer;
use pipeline_rl::runtime::{HostTensor, Runtime};
use pipeline_rl::util::cli::Args;
use pipeline_rl::util::timer::Stopwatch;
use pipeline_rl::util::Rng;

fn rss_kb() -> u64 {
    std::fs::read_to_string("/proc/self/statm")
        .ok()
        .and_then(|s| s.split_whitespace().nth(1).map(|p| p.parse::<u64>().ok()))
        .flatten()
        .map(|pages| pages * 4)
        .unwrap_or(0)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let seconds = args.f64_or("seconds", 30.0)?;
    let do_train = args.bool("train");
    let variant = args.str_or("variant", "tiny");

    let mut rt = Runtime::new()?;
    let params = rt.init_params(&variant, 1)?;
    let mut cfg = EngineCfg::new(&variant);
    cfg.max_new_tokens = usize::MAX / 2; // slots never finish
    let mut eng = Engine::new(&mut rt, cfg, &params, 0, Rng::new(1))?;
    eng.set_weights(1, &params)?;
    let gen = TaskGen::curriculum_small();
    let tk = Tokenizer::new();
    for i in 0..eng.n_slots() {
        let p = gen.problem(i as u64);
        let toks = tk.encode(&p.prompt).unwrap();
        eng.add_request(p, toks, i as u64);
    }
    // warm up compilation + first steps
    for _ in 0..5 {
        eng.step()?;
    }

    let train_graph = if do_train {
        Some(rt.graph(&variant, "train")?)
    } else {
        None
    };
    let v = rt.manifest.variant(&variant)?.clone();
    let (b, t) = (v.train_batch, v.seq_len);
    let p = v.params.len();
    let m = rt.zero_opt_state(&variant)?;
    let vv = rt.zero_opt_state(&variant)?;

    let rss0 = rss_kb();
    let sw = Stopwatch::new();
    let mut steps = 0u64;
    let mut train_steps = 0u64;
    let mut last_report = 0.0;
    while sw.seconds() < seconds {
        // decode step (slots wrap at max_seq via Length finish + refill)
        let out = eng.step()?;
        if out.idle {
            for i in 0..eng.n_slots() {
                let pb = gen.problem(steps + i as u64);
                let toks = tk.encode(&pb.prompt).unwrap();
                eng.add_request(pb, toks, steps + i as u64);
            }
        }
        steps += 1;
        if let Some(g) = &train_graph {
            if steps % 16 == 0 {
                let mut inputs: Vec<HostTensor> = Vec::with_capacity(3 * p + 12);
                inputs.extend(params.iter().cloned());
                inputs.extend(m.iter().cloned());
                inputs.extend(vv.iter().cloned());
                inputs.push(HostTensor::scalar_f32(1.0));
                inputs.push(HostTensor::zeros_i32(&[b, t]));
                inputs.push(HostTensor::zeros_i32(&[b, t]));
                inputs.push(HostTensor::zeros_i32(&[b, t]));
                inputs.push(HostTensor::zeros_f32(&[b, t]));
                inputs.push(HostTensor::zeros_f32(&[b, t]));
                inputs.push(HostTensor::zeros_f32(&[b, t]));
                inputs.push(HostTensor::zeros_f32(&[b, t]));
                inputs.push(HostTensor::scalar_f32(1e-3));
                inputs.push(HostTensor::scalar_f32(5.0));
                inputs.push(HostTensor::scalar_f32(0.0));
                inputs.push(HostTensor::scalar_f32(0.0));
                g.run_host(&inputs)?;
                train_steps += 1;
            }
        }
        if sw.seconds() - last_report >= 5.0 {
            last_report = sw.seconds();
            println!(
                "t={:5.1}s steps={steps} train={train_steps} rss={} KB (Δ {} KB)",
                sw.seconds(),
                rss_kb(),
                rss_kb() as i64 - rss0 as i64
            );
        }
    }
    let drss = rss_kb() as i64 - rss0 as i64;
    let per_step = drss as f64 / steps as f64;
    println!(
        "\nsoak done: {steps} decode steps, {train_steps} train steps, \
         ΔRSS {drss} KB ({per_step:.2} KB/step)"
    );
    if per_step > 8.0 {
        println!("WARNING: possible leak (> 8 KB/step)");
        std::process::exit(1);
    }
    Ok(())
}
