//! Fig 7 — impact of in-flight weight updates on on-policyness (§5.1).
//!
//! Protocol (paper): train briefly, saving a checkpoint after every
//! optimizer step. Then, from three starting checkpoints C, generate
//! sequences under the *mixed* behavior policy that swaps to the next
//! checkpoint every L/g tokens (the in-flight replay), and measure the
//! per-token KL between the mixed policy's sampling distributions and
//! the final on-policy checkpoint C+g, as a function of the lag g:
//!
//! * `pipeline (stale KV)`   — in-flight swaps, KV cache retained
//! * `pipeline (recompute)`  — in-flight swaps, KV rebuilt per swap
//! * `conventional lag g`    — whole sequence from C, scored against C+g
//!
//! Expected shape (paper Fig 7): the conventional KL grows with lag while
//! both pipeline variants stay low, stale KV only slightly above
//! recompute — the §5.1 justification for retaining the cache.
//!
//! ```bash
//! cargo run --release --example kl_inflight -- --steps 24 --lags 1,2,4,8,16
//! ```

use pipeline_rl::config::RunConfig;
use pipeline_rl::coordinator::{self, klstudy::{replay_kl, Swap}};
use pipeline_rl::model::checkpoint::TrainState;
use pipeline_rl::runtime::HostTensor;
use pipeline_rl::util::cli::Args;
use pipeline_rl::util::logging::{self, Level};

fn main() -> anyhow::Result<()> {
    logging::set_level(Level::Warn);
    let args = Args::parse_env();
    let variant = args.str_or("variant", "tiny");
    let steps = args.usize_or("steps", 24)?;
    let lags: Vec<usize> = args
        .str_or("lags", "1,2,4,8")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    let max_lag = *lags.iter().max().unwrap();
    let ckpt_dir = std::env::temp_dir().join("prl_kl_ckpts");
    std::fs::create_dir_all(&ckpt_dir)?;

    // ---- phase 1: train with per-step checkpointing ----
    println!("== phase 1: {steps} RL steps with per-step checkpoints ==");
    let mut cfg = RunConfig::default();
    cfg.variant = variant.clone();
    cfg.sft_steps = args.usize_or("sft-steps", 30)?;
    cfg.rl_steps = steps;
    cfg.max_new_tokens = 24;
    cfg.checkpoint.every = 1;
    cfg.checkpoint.dir = Some(ckpt_dir.to_string_lossy().to_string());
    cfg.log_every = 0;
    cfg.seed = args.usize_or("seed", 5)? as u64;
    coordinator::run(cfg.clone(), None)?;

    let load = |step: usize| -> anyhow::Result<Vec<HostTensor>> {
        let p = ckpt_dir.join(TrainState::file_name(step as u64));
        Ok(TrainState::load(&p)?.params)
    };

    // three training stages, like the paper's checkpoints 0/100/190
    let starts = [1usize, (steps / 2).max(1), steps.saturating_sub(max_lag).max(1)];
    println!("\n== phase 2: per-token KL(behavior ‖ on-policy C+g) ==");
    println!(
        "{:>6} {:>6} {:>18} {:>18} {:>14}",
        "start", "lag g", "pipe stale-KV", "pipe recompute", "conventional"
    );
    for &start in &starts {
        for &g in &lags {
            if start + g > steps {
                continue;
            }
            let kl_stale = replay_kl(&cfg, &load, start, g, Swap::InFlight { recompute: false })?;
            let kl_rec = replay_kl(&cfg, &load, start, g, Swap::InFlight { recompute: true })?;
            let kl_conv = replay_kl(&cfg, &load, start, g, Swap::None)?;
            println!("{start:>6} {g:>6} {kl_stale:>18.5} {kl_rec:>18.5} {kl_conv:>14.5}");
        }
    }
    println!("\nexpected shape (Fig 7): conventional grows with g; both pipeline");
    println!("variants stay low, stale-KV slightly above recompute.");
    Ok(())
}
