//! Analytic model explorer — Fig 8 (U(h)), Fig 9 (throughput vs g_max),
//! Fig 3b (Pareto frontiers) and the Appendix A.4 case study, from the
//! calibrated flash-unit performance model.
//!
//! ```bash
//! cargo run --release --example pareto -- --n 128 --b 128 --l 2048
//! ```

use pipeline_rl::perfmodel::{
    search, throughput::Workload, AccelModel,
};
use pipeline_rl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let mut w = Workload::paper_a4();
    w.n = args.usize_or("n", 128)?;
    w.b = args.usize_or("b", 128)?;
    w.l_max = args.usize_or("l", 2048)?;
    w.tau = args.f64_or("tau", 4.92)?;

    println!("== Fig 8: H100 utilization model U(h) ==");
    let m = AccelModel::h100();
    println!("{:>6} {:>9} {:>9}", "h", "U_raw", "U_padded");
    for (h, raw, pad) in m.table(&[1, 2, 4, 8, 16, 32, 64, 96, 128, 192, 256, 384, 512, 1024, 2048]) {
        println!("{h:>6} {raw:>9.4} {pad:>9.4}");
    }

    println!("\n== Fig 9: throughput vs max lag (N={}, B={}) ==", w.n, w.b);
    let budgets: Vec<usize> = vec![1, 2, 4, 8, 16, 32, 64, 96, 133, 192, 256, 384, 512];
    let grid: Vec<usize> = (4..=512).step_by(4).collect();
    let pipe = search::search_pipeline_configs(&w, &budgets, &grid);
    let conv = search::conventional_curve(&w, &budgets);
    println!(
        "{:>7} {:>12} {:>16} {:>12} {:>8}",
        "g_max", "r_pipeline", "(I, H)", "r_conv", "speedup"
    );
    for ((budget, best), c) in pipe.iter().zip(&conv) {
        match best {
            Some(p) => println!(
                "{budget:>7} {:>12.2} {:>16} {:>12.2} {:>8.2}",
                p.r,
                format!("({}, {})", p.i, p.h),
                c.r,
                p.r / c.r
            ),
            None => println!("{budget:>7} {:>12} {:>16} {:>12.2}", "-", "-", c.r),
        }
    }

    println!("\n== Appendix A.4 case study ==");
    let cs = search::case_study(&w);
    println!(
        "pipeline : r_gen {:.2}, r_train {:.2}, r {:.2}  (H={}, I={}, g_max={})",
        cs.pipe.r_gen, cs.pipe.r_train, cs.pipe.r, cs.pipe.h, cs.pipe.i, cs.pipe.lag_steps
    );
    println!(
        "convent. : r_gen {:.2}, r_train {:.2}, r {:.2}  (G={})",
        cs.conv.r_gen, cs.conv.r_train, cs.conv.r, cs.conv.g
    );
    println!("speedup  : {:.2}x   (paper: 1.57x at g_max ~ 133)", cs.speedup);

    println!("\n== Fig 3b: effectiveness/throughput frontier points ==");
    let (pipe_pts, conv_pts) = search::pareto_sweep(&w);
    println!("pipeline      : {:?}", round_pts(&pipe_pts));
    println!("conventional  : {:?}", round_pts(&conv_pts));
    Ok(())
}

fn round_pts(pts: &[(f64, f64)]) -> Vec<(f64, f64)> {
    pts.iter()
        .map(|(a, b)| ((a * 1000.0).round() / 1000.0, (b * 100.0).round() / 100.0))
        .collect()
}
