//! End-to-end driver — the repository's headline experiment (Fig 5/6/10).
//!
//! Trains the transformer policy on synthetic arithmetic reasoning with
//! the FULL stack (AOT Pallas kernels → PJRT engine → broker pipeline),
//! comparing PipelineRL against Conventional-RL baselines from the *same*
//! SFT warmup:
//!
//! ```bash
//! cargo run --release --example train_pipeline_rl -- \
//!     --variant small --steps 120 --modes pipeline,conv8,conv32 \
//!     --out runs/
//! ```
//!
//! For each mode it logs reward-vs-time (Fig 5a), reward-vs-samples
//! (Fig 5b), samples-vs-time (Fig 5c), max-lag and ESS per step (Fig 6),
//! writes the full metric series as JSON, evaluates held-out success
//! rates (Table 1 protocol) and prints a comparison table. `--modes
//! conv64` reproduces the Fig 10 divergence probe.

use pipeline_rl::config::{Mode, RunConfig};
use pipeline_rl::coordinator::{self, eval};
use pipeline_rl::data::task::TaskKind;
use pipeline_rl::metrics::RunReport;
use pipeline_rl::runtime::Runtime;
use pipeline_rl::util::cli::Args;
use pipeline_rl::util::logging::{self, Level};

struct ModeResult {
    name: String,
    report: RunReport,
    wall: f64,
    final_success: f64,
    time_to_threshold: Option<f64>,
    samples_to_threshold: Option<f64>,
}

fn main() -> anyhow::Result<()> {
    logging::set_level(Level::Info);
    let args = Args::parse_env();
    let variant = args.str_or("variant", "small");
    let steps = args.usize_or("steps", 80)?;
    let sft_steps = args.usize_or("sft-steps", 120)?;
    let seed = args.usize_or("seed", 1)? as u64;
    let out_dir = args.str_or("out", "runs");
    let threshold = args.f64_or("threshold", 0.5)?;
    let modes_s = args.str_or("modes", "pipeline,conv8");

    let mut base = RunConfig::default();
    base.variant = variant.clone();
    base.rl_steps = steps;
    base.sft_steps = sft_steps;
    base.seed = seed;
    base.group_size = args.usize_or("group", 4)?;
    base.max_new_tokens = args.usize_or("max-new", 48)?;
    base.task.kinds = vec![TaskKind::Add, TaskKind::Sub, TaskKind::Copy];
    base.task.max_operand = args.usize_or("max-operand", 99)? as i64;
    base.lr = args.f64_or("lr", 3e-4)?;
    base.log_every = args.usize_or("log-every", 10)?;

    // one shared warmup => all modes start from the same "base model"
    println!("== SFT warmup ({sft_steps} steps, variant {variant}) ==");
    let warm = {
        let mut rt = Runtime::new()?;
        let hub = pipeline_rl::metrics::MetricsHub::new();
        coordinator::warmup::run_sft(&mut rt, &base, &hub)?
    };

    let mut results = Vec::new();
    for mode_name in modes_s.split(',') {
        let mut cfg = base.clone();
        cfg.mode = parse_mode(mode_name)?;
        println!("\n== training: {} ({} optimizer steps) ==", mode_name, steps);
        let summary = coordinator::run(cfg.clone(), Some(warm.clone()))?;

        let mut rt = Runtime::new()?;
        let ev = eval::evaluate(&mut rt, &cfg, &summary.final_params, 60)?;
        let rvt = summary.report.series("reward_vs_time").cloned().unwrap_or_default();
        let rvs = summary.report.series("reward_vs_samples").cloned().unwrap_or_default();
        let res = ModeResult {
            name: mode_name.to_string(),
            wall: summary.wall_seconds,
            final_success: ev.success_rate(),
            time_to_threshold: rvt.first_crossing(threshold, 5).map(|(t, _)| t),
            samples_to_threshold: rvs.first_crossing(threshold, 5).map(|(_, x)| x),
            report: summary.report,
        };
        let path = std::path::Path::new(&out_dir)
            .join(format!("{}_{}.json", variant, mode_name));
        res.report.save_json(&path)?;
        println!("  series written to {}", path.display());
        results.push(res);
    }

    // ---- Fig 5/6 style comparison table ----
    println!("\n==================== comparison ====================");
    println!(
        "{:<12} {:>8} {:>9} {:>10} {:>11} {:>8} {:>8}",
        "mode", "wall(s)", "samples", "t->R=.5", "S->R=.5", "ESS", "eval%"
    );
    for r in &results {
        let ess = r
            .report
            .series("train/ess")
            .map(|s| s.tail_mean(10))
            .unwrap_or(f64::NAN);
        println!(
            "{:<12} {:>8.1} {:>9} {:>10} {:>11} {:>8.3} {:>8.1}",
            r.name,
            r.wall,
            r.report.counters.get("samples_trained").copied().unwrap_or(0.0),
            r.time_to_threshold
                .map(|t| format!("{t:.1}s"))
                .unwrap_or_else(|| "-".into()),
            r.samples_to_threshold
                .map(|s| format!("{s:.0}"))
                .unwrap_or_else(|| "-".into()),
            ess,
            100.0 * r.final_success,
        );
    }
    if let (Some(p), Some(c)) = (
        results.iter().find(|r| r.name == "pipeline"),
        results.iter().find(|r| r.name.starts_with("conv")),
    ) {
        if let (Some(tp), Some(tc)) = (p.time_to_threshold, c.time_to_threshold) {
            println!(
                "\nPipelineRL reached R={threshold} {:.2}x faster than {} (Fig 5a)",
                tc / tp,
                c.name
            );
        }
        let lag_p = p.report.series("train/max_lag").map(|s| s.tail_mean(10));
        let lag_c = c.report.series("train/max_lag").map(|s| s.tail_mean(10));
        println!(
            "max lag (steps): pipeline {:.1} vs {} {:.1} (Fig 6a)",
            lag_p.unwrap_or(f64::NAN),
            c.name,
            lag_c.unwrap_or(f64::NAN)
        );
    }
    Ok(())
}

fn parse_mode(s: &str) -> anyhow::Result<Mode> {
    if s == "pipeline" {
        return Ok(Mode::Pipeline);
    }
    if let Some(g) = s.strip_prefix("conv") {
        return Ok(Mode::Conventional { g: g.parse()? });
    }
    anyhow::bail!("unknown mode {s:?} (use pipeline | convN)")
}
