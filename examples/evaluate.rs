//! Table 1 analogue: held-out success rates of base / SFT / RL-trained
//! models, per task family (our MATH500 / AIME24 stand-ins).
//!
//! ```bash
//! cargo run --release --example evaluate -- --variant tiny --rl-steps 40
//! # or evaluate an existing checkpoint:
//! cargo run --release --example evaluate -- --checkpoint runs/step00040.ckpt
//! ```

use pipeline_rl::config::RunConfig;
use pipeline_rl::coordinator::{self, eval};
use pipeline_rl::data::task::TaskKind;
use pipeline_rl::model::checkpoint::load_params_any;
use pipeline_rl::runtime::Runtime;
use pipeline_rl::util::cli::Args;
use pipeline_rl::util::logging::{self, Level};

fn main() -> anyhow::Result<()> {
    logging::set_level(Level::Info);
    let args = Args::parse_env();
    let mut cfg = RunConfig::default();
    cfg.variant = args.str_or("variant", "tiny");
    cfg.sft_steps = args.usize_or("sft-steps", 60)?;
    cfg.rl_steps = args.usize_or("rl-steps", 40)?;
    cfg.max_new_tokens = args.usize_or("max-new", 32)?;
    cfg.task.kinds = vec![TaskKind::Add, TaskKind::Sub, TaskKind::Copy];
    cfg.task.max_operand = args.usize_or("max-operand", 50)? as i64;
    cfg.seed = args.usize_or("seed", 2)? as u64;
    cfg.log_every = 20;
    let n_eval = args.usize_or("n-eval", 100)?;

    let mut rt = Runtime::new()?;
    let mut rows: Vec<(String, eval::EvalReport, f64)> = Vec::new();

    if let Some(path) = args.flags.get("checkpoint") {
        let (variant, step, params) = load_params_any(std::path::Path::new(path))?;
        cfg.variant = variant;
        let rep = eval::evaluate(&mut rt, &cfg, &params, n_eval)?;
        rows.push((format!("checkpoint step {step}"), rep, f64::NAN));
    } else {
        // base (random init) -> SFT -> RL, like Table 1's progression
        let base_params = rt.init_params(&cfg.variant, cfg.seed as i32)?;
        let rep_base = eval::evaluate(&mut rt, &cfg, &base_params, n_eval)?;
        rows.push(("base (random init)".into(), rep_base, 0.0));

        let hub = pipeline_rl::metrics::MetricsHub::new();
        let sft_params = coordinator::warmup::run_sft(&mut rt, &cfg, &hub)?;
        let rep_sft = eval::evaluate(&mut rt, &cfg, &sft_params, n_eval)?;
        rows.push((format!("SFT ({} steps)", cfg.sft_steps), rep_sft, 0.0));

        println!("== RL training ({} steps, PipelineRL) ==", cfg.rl_steps);
        let summary = coordinator::run(cfg.clone(), Some(sft_params))?;
        let rep_rl = eval::evaluate(&mut rt, &cfg, &summary.final_params, n_eval)?;
        let samples = summary.report.counters.get("samples_trained").copied().unwrap_or(0.0);
        rows.push((
            format!("PipelineRL ({} steps)", cfg.rl_steps),
            rep_rl,
            samples,
        ));
    }

    println!("\n================== Table 1 analogue ==================");
    println!(
        "{:<24} {:>8} {:>9} {:>9} {:>8}",
        "method", "overall", "# samples", "mean len", "eos rate"
    );
    for (name, rep, samples) in &rows {
        println!(
            "{:<24} {:>7.1}% {:>9} {:>9.1} {:>8.2}",
            name,
            100.0 * rep.success_rate(),
            if samples.is_nan() { "-".to_string() } else { format!("{samples}") },
            rep.mean_gen_len,
            rep.eos_rate,
        );
    }
    println!("\nper task family (correct/total):");
    for (name, rep, _) in &rows {
        let detail: Vec<String> = rep
            .by_kind
            .iter()
            .map(|(k, (c, n))| format!("{k}: {c}/{n}"))
            .collect();
        println!("  {:<24} {}", name, detail.join("  "));
    }
    Ok(())
}
