"""L2: the transformer policy model (fwd / decode / train / score graphs).

This is the Qwen-2.5 stand-in (DESIGN.md §2): a decoder-only transformer
with RMSNorm, RoPE, GELU MLP and a tied embedding/softmax head, expressed
as pure functions over a *flat list* of parameter arrays (canonical order
= `configs.ModelConfig.param_specs()`, mirrored by the rust manifest).

These computations are exported by aot.py, one HLO artifact each:

  init        seed -> params
  decode      one continuous-batching engine step for all slots (the
              request-path hot loop; calls kernels.decode_attention and
              samples in-graph via Gumbel-max so one PJRT execution
              produces the next token AND its behavior logprob)
  decode_paged  the same step against a *paged* physical KV layout: a
              shared device block pool [n_blocks, L, 2, bs, H, hd]
              addressed through a per-row block-table input, with CoW
              forks as real device block copies (copy_src/copy_dst
              lanes) and the pool operand donated (input_output_alias)
              for true in-place update. Token-for-token identical to
              `decode` — `[kv] layout = dense|paged` on the rust side
              picks the artifact; dense stays the bit-for-bit fallback
  prefill_chunk / prefill_chunk_paged
              the chunked-prefill generalization of decode: W forced
              tokens per row per dispatch (per-row start/valid-length
              lanes), so a P-token prompt ingestion or KV replay costs
              ceil(P/W) dispatches instead of P. Lane vlen-1 runs the
              same Gumbel-max sampling head, so a chunk that reaches the
              end of a row's stream also samples its first free token.
              Rows with no prefill work ride along with vlen = 1
              (ordinary decode) or vlen = 0 (parked)
  train       fused fwd+bwd+Adam IS-REINFORCE optimizer step (calls
              kernels.reinforce_loss with its custom-VJP Pallas backward
              and kernels.adam)
  sft         cross-entropy warmup step (the "base model" stand-in)
  score/score_full   teacher-forced per-token logprobs (preprocessor ref
              logprobs; Fig 7 KL study) — calls kernels.flash_attention

Conventions (rust side must match — recorded in artifacts/manifest.json):
  * tokens[b, t] with t=0 the BOS; predictions are aligned so that index t
    of lp / mask / behavior_lp / advantage refers to predicting
    tokens[b, t+1]; the last column of mask MUST be 0.
  * seg[b, t] = 0 for padding; packed sequences get ids 1, 2, ...;
    pos[b, t] restarts at 0 for each segment.
  * metrics vector layout: see METRIC_NAMES / SFT_METRIC_NAMES.
"""

import jax
import jax.numpy as jnp

from . import configs, vocab
from .kernels import adam as adam_k
from .kernels import attention as attn_k
from .kernels import ref
from .kernels import reinforce_loss as loss_k

METRIC_NAMES = [
    "loss", "pg_loss", "v_loss", "ess", "mean_kl", "clip_frac",
    "grad_norm", "entropy", "mean_ratio", "n_tokens",
]
SFT_METRIC_NAMES = ["loss", "grad_norm", "n_tokens"]


# ---------------------------------------------------------------------------
# parameter handling
# ---------------------------------------------------------------------------

def init_params(cfg: configs.ModelConfig, seed):
    """Build the flat parameter list from an int32 seed (traceable)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name == "value_head":
            params.append(jnp.zeros(shape, jnp.float32))
        elif len(shape) == 1:  # norm scales
            params.append(jnp.ones(shape, jnp.float32))
        else:
            params.append(jax.random.normal(sub, shape, jnp.float32) * 0.02)
    return params


def unpack(cfg: configs.ModelConfig, params):
    """flat list -> dict by name."""
    return {name: p for (name, _), p in zip(cfg.param_specs(), params)}


# ---------------------------------------------------------------------------
# shared forward pieces
# ---------------------------------------------------------------------------

def _split_heads(x, n_heads):
    b = x.shape[:-1]
    return x.reshape(*b, n_heads, x.shape[-1] // n_heads)


def _merge_heads(x):
    b = x.shape[:-2]
    return x.reshape(*b, x.shape[-2] * x.shape[-1])


def forward_hidden(cfg, params, tokens, seg, pos, use_pallas_attn):
    """Teacher-forced forward. tokens/seg/pos: [B, T] int32.
    Returns final-normed hidden states [B, T, d]."""
    p = unpack(cfg, params)
    x = p["embed"][tokens]                                   # [B, T, d]
    attention = (
        attn_k.flash_attention if use_pallas_attn else ref.causal_segment_attention
    )
    for l in range(cfg.n_layers):
        h = ref.rmsnorm(x, p[f"l{l}.ln1"])
        q = ref.rope(_split_heads(h @ p[f"l{l}.wq"], cfg.n_heads), pos)
        k = ref.rope(_split_heads(h @ p[f"l{l}.wk"], cfg.n_heads), pos)
        v = _split_heads(h @ p[f"l{l}.wv"], cfg.n_heads)
        att = attention(q, k, v, seg)
        x = x + _merge_heads(att) @ p[f"l{l}.wo"]
        h2 = ref.rmsnorm(x, p[f"l{l}.ln2"])
        x = x + jax.nn.gelu(h2 @ p[f"l{l}.w1"]) @ p[f"l{l}.w2"]
    return ref.rmsnorm(x, p["final_norm"])


# ---------------------------------------------------------------------------
# decode (engine hot loop)
# ---------------------------------------------------------------------------

def kv_shape(cfg):
    return (cfg.n_layers, 2, cfg.gen_batch, cfg.max_seq, cfg.n_heads, cfg.head_dim)


def blocks_per_row(cfg):
    """Logical blocks per slot. kv_block_size must divide max_seq: the
    gathered paged view is then exactly the dense timeline, which is the
    precondition for bit-for-bit dense/paged parity."""
    assert cfg.max_seq % cfg.kv_block_size == 0, (cfg.max_seq, cfg.kv_block_size)
    return cfg.max_seq // cfg.kv_block_size


def kv_pool_shape(cfg):
    """Device block pool [n_blocks, L, 2, block_size, H, hd].

    The pool is sized for worst-case zero sharing (every slot holds its
    full private timeline) plus one *trash block*: physical index
    n_blocks-1 is never handed out by the rust allocator and every parked
    row's table points at it, so parked scatters land somewhere harmless
    and identical (parked rows all write the PAD token at the park
    position — duplicate scatters of equal values are deterministic).
    The allocator's refcounted sharing means real runs use strictly fewer
    blocks than this worst case; the pool bound is what lets the graph
    stay static while sharing/CoW govern the *working set*.
    """
    n_blocks = cfg.gen_batch * blocks_per_row(cfg) + 1
    return (n_blocks, cfg.n_layers, 2, cfg.kv_block_size, cfg.n_heads, cfg.head_dim)


def decode_step(cfg, params, kv, pos, cur_tok, gumbel, force_tok, force_mask, temp):
    """One engine step for every slot.

    kv: [L, 2, B, Tmax, H, hd]; pos[b] = cache index the current token is
    written at (and attended up to); cur_tok: the token being fed in;
    gumbel: [B, V] Gumbel(0,1) noise from the rust RNG; force_tok/mask:
    continuous-batching prompt forcing (prefill-through-decode).

    Returns (next_tok[B], chosen_lp[B], logprobs[B, V], kv', ent[B]).
    chosen_lp / logprobs are under the actual sampling distribution
    softmax(logits / temp) — the true behavior policy mu.
    """
    p = unpack(cfg, params)
    bsz = cfg.gen_batch
    rows = jnp.arange(bsz)
    x = p["embed"][cur_tok]                                  # [B, d]
    for l in range(cfg.n_layers):
        h = ref.rmsnorm(x, p[f"l{l}.ln1"])
        q = ref.rope(_split_heads(h @ p[f"l{l}.wq"], cfg.n_heads), pos)
        k = ref.rope(_split_heads(h @ p[f"l{l}.wk"], cfg.n_heads), pos)
        v = _split_heads(h @ p[f"l{l}.wv"], cfg.n_heads)
        kv = kv.at[l, 0, rows, pos].set(k)
        kv = kv.at[l, 1, rows, pos].set(v)
        att = attn_k.decode_attention(q, kv[l, 0], kv[l, 1], pos)
        x = x + _merge_heads(att) @ p[f"l{l}.wo"]
        h2 = ref.rmsnorm(x, p[f"l{l}.ln2"])
        x = x + jax.nn.gelu(h2 @ p[f"l{l}.w1"]) @ p[f"l{l}.w2"]
    next_tok, chosen_lp, lp_all, ent = _sample_head(
        cfg, p, x, gumbel, force_tok, force_mask, temp
    )
    return next_tok, chosen_lp, lp_all, kv, ent


def _sample_head(cfg, p, x, gumbel, force_tok, force_mask, temp):
    """Shared logits → Gumbel-max sampling tail of both decode variants.
    One definition so dense and paged cannot drift numerically."""
    hN = ref.rmsnorm(x, p["final_norm"])
    logits = (hN @ p["embed"].T) / temp                      # [B, V]
    lp_all = jax.nn.log_softmax(logits, axis=-1)
    sampled = jnp.argmax(logits + gumbel, axis=-1).astype(jnp.int32)
    next_tok = jnp.where(force_mask > 0.5, force_tok, sampled).astype(jnp.int32)
    chosen_lp = jnp.take_along_axis(lp_all, next_tok[:, None], axis=-1)[:, 0]
    ent = -jnp.sum(jnp.exp(lp_all) * lp_all, axis=-1)
    return next_tok, chosen_lp, lp_all, ent


def decode_step_paged(cfg, params, pool, table, copy_src, copy_dst,
                      pos, cur_tok, gumbel, force_tok, force_mask, temp):
    """One engine step against the paged device KV pool.

    pool: [N, L, 2, bs, H, hd] shared block pool (kv_pool_shape); the last
    physical block is the trash block (see kv_pool_shape). table: [B, NB]
    int32 — logical block j of row b is physical block table[b, j]; parked
    rows' tables point every slot at trash. copy_src/copy_dst: [B] int32
    CoW-fork lanes — before any write, each row copies one whole block
    pool[copy_src[b]] -> pool[copy_dst[b]] (the allocator reports at most
    one fork per row per step: a divergent write crosses into exactly one
    block); rows without a fork carry trash->trash, a deterministic no-op.

    The current token's K/V scatter into (table[b, pos//bs], pos % bs),
    attention gathers by block index masked to <= pos — so the rust
    allocator's refcounted sharing and forks govern *physical* memory
    while token output stays bit-identical to decode_step (parity test in
    python/tests/test_model.py).

    Returns (next_tok[B], chosen_lp[B], logprobs[B, V], pool', ent[B]).
    """
    p = unpack(cfg, params)
    rows = jnp.arange(cfg.gen_batch)
    bs = cfg.kv_block_size
    # CoW forks first: real device block copies, before any write lands
    pool = pool.at[copy_dst].set(pool[copy_src])
    blk = table[rows, pos // bs]                             # [B] write block
    off = pos % bs
    x = p["embed"][cur_tok]                                  # [B, d]
    for l in range(cfg.n_layers):
        h = ref.rmsnorm(x, p[f"l{l}.ln1"])
        q = ref.rope(_split_heads(h @ p[f"l{l}.wq"], cfg.n_heads), pos)
        k = ref.rope(_split_heads(h @ p[f"l{l}.wk"], cfg.n_heads), pos)
        v = _split_heads(h @ p[f"l{l}.wv"], cfg.n_heads)
        pool = pool.at[blk, l, 0, off].set(k)
        pool = pool.at[blk, l, 1, off].set(v)
        att = attn_k.paged_decode_attention(
            q, pool[:, l, 0], pool[:, l, 1], table, pos
        )
        x = x + _merge_heads(att) @ p[f"l{l}.wo"]
        h2 = ref.rmsnorm(x, p[f"l{l}.ln2"])
        x = x + jax.nn.gelu(h2 @ p[f"l{l}.w1"]) @ p[f"l{l}.w2"]
    next_tok, chosen_lp, lp_all, ent = _sample_head(
        cfg, p, x, gumbel, force_tok, force_mask, temp
    )
    return next_tok, chosen_lp, lp_all, pool, ent


def prefill_chunk(cfg, params, kv, start, chunk_toks, vlen, gumbel,
                  force_tok, force_mask, temp):
    """Chunked prefill: up to W forced tokens per row in one dispatch.

    chunk_toks: [B, W] — lane j of row b feeds token chunk_toks[b, j] at
    cache position start[b] + j, for j < vlen[b]; lanes >= vlen[b] are
    inert (tokens replaced by PAD, K/V zeroed and scattered at the park
    position max_seq-1). All W K/V lanes of a layer are scattered before
    its attention, so the per-lane position mask (keys 0..=start+j) gives
    causal within-chunk + past-KV attention in one batched kernel call
    (kernels.attention.chunk_decode_attention). The sampling head runs on
    lane max(vlen-1, 0) — when the chunk ends exactly at a row's stream
    end the dispatch also samples, identically to decode_step at that
    position. Rows with vlen = 0 park (start = max_seq-1, like an idle
    decode row).

    Bit-exactness contract (the parity tests' claim): every projection /
    norm / MLP runs per lane at the same [B, ...] shapes as decode_step,
    and the chunk attention kernel unrolls its lanes over byte-for-byte
    `_decode_kernel` math — XLA CPU contractions are not bit-stable
    across a fused [B*W, ...] batch, so the chunk fuses *dispatches*
    (one executable, one KV round-trip, W scatters per layer), never
    reduction shapes. A chunk is therefore bit-identical to feeding its
    tokens through decode_step one at a time, for every valid lane. Only
    the park column differs: inert lanes write zeros where legacy parked
    rows write rope'd PAD garbage — both are dead values no valid query
    ever attends (mask col <= pos).

    Returns (next_tok[B], chosen_lp[B], logprobs[B, V], kv', ent[B]) —
    the decode_step signature, so the rust engine reads it back through
    the same lanes.
    """
    p = unpack(cfg, params)
    bsz = cfg.gen_batch
    w = cfg.prefill_chunk
    rows = jnp.arange(bsz)
    park = cfg.max_seq - 1
    lane = jnp.arange(w)
    valid = lane[None, :] < vlen[:, None]                    # [B, W]
    pos_w = jnp.where(valid, start[:, None] + lane[None, :], park)
    toks_w = jnp.where(valid, chunk_toks, vocab.PAD_ID)
    xs = [p["embed"][toks_w[:, j]] for j in range(w)]        # W x [B, d]
    for l in range(cfg.n_layers):
        qs, ks, vs = [], [], []
        for j in range(w):
            h = ref.rmsnorm(xs[j], p[f"l{l}.ln1"])
            qs.append(ref.rope(
                _split_heads(h @ p[f"l{l}.wq"], cfg.n_heads), pos_w[:, j]))
            ks.append(ref.rope(
                _split_heads(h @ p[f"l{l}.wk"], cfg.n_heads), pos_w[:, j]))
            vs.append(_split_heads(h @ p[f"l{l}.wv"], cfg.n_heads))
        # inert lanes scatter zeros at park: duplicate writes of equal
        # values, deterministic regardless of scatter order
        k_all = jnp.where(valid[..., None, None], jnp.stack(ks, axis=1), 0.0)
        v_all = jnp.where(valid[..., None, None], jnp.stack(vs, axis=1), 0.0)
        kv = kv.at[l, 0, rows[:, None], pos_w].set(k_all)
        kv = kv.at[l, 1, rows[:, None], pos_w].set(v_all)
        att = attn_k.chunk_decode_attention(
            jnp.stack(qs, axis=1), kv[l, 0], kv[l, 1], pos_w)
        for j in range(w):
            xj = xs[j] + _merge_heads(att[:, j]) @ p[f"l{l}.wo"]
            h2 = ref.rmsnorm(xj, p[f"l{l}.ln2"])
            xs[j] = xj + jax.nn.gelu(h2 @ p[f"l{l}.w1"]) @ p[f"l{l}.w2"]
    # lane vlen-1 feeds the sampling head (lane 0 for parked rows — the
    # same PAD-forward a legacy parked row runs; its output is discarded)
    x_last = xs[0]
    for j in range(1, w):
        x_last = jnp.where((lane[j] < vlen)[:, None], xs[j], x_last)
    next_tok, chosen_lp, lp_all, ent = _sample_head(
        cfg, p, x_last, gumbel, force_tok, force_mask, temp
    )
    return next_tok, chosen_lp, lp_all, kv, ent


def prefill_chunk_paged(cfg, params, pool, table, copy_src, copy_dst,
                        start, chunk_toks, vlen, gumbel,
                        force_tok, force_mask, temp):
    """Chunked prefill against the paged device KV pool.

    Same chunk semantics as prefill_chunk; the W K/V scatters address the
    block pool through the same table/copy-lane operands as
    decode_step_paged — lane j of row b writes block table[b, (start+j)
    // bs] at offset (start+j) % bs. Inert lanes (j >= vlen[b], and every
    lane of a parked row) scatter *zeros* directly into the trash block
    at offset bs-1, never touching a real block. CoW copy lanes run
    before any write, exactly like the single-step graph.

    Bit-exactness: same per-lane structure as prefill_chunk (see its
    docstring) — the batched op is kernels.attention.
    paged_chunk_decode_attention, whose gather-then-dense body inherits
    the dense/paged parity argument of `_paged_decode_kernel`.

    Returns (next_tok[B], chosen_lp[B], logprobs[B, V], pool', ent[B]).
    """
    p = unpack(cfg, params)
    bsz = cfg.gen_batch
    w = cfg.prefill_chunk
    rows = jnp.arange(bsz)
    bs = cfg.kv_block_size
    park = cfg.max_seq - 1
    trash = kv_pool_shape(cfg)[0] - 1
    lane = jnp.arange(w)
    valid = lane[None, :] < vlen[:, None]                    # [B, W]
    pos_w = jnp.where(valid, start[:, None] + lane[None, :], park)
    toks_w = jnp.where(valid, chunk_toks, vocab.PAD_ID)
    # CoW forks first: real device block copies, before any write lands
    pool = pool.at[copy_dst].set(pool[copy_src])
    blk = jnp.where(valid, table[rows[:, None], pos_w // bs], trash)
    off = pos_w % bs                                         # park -> bs-1
    xs = [p["embed"][toks_w[:, j]] for j in range(w)]        # W x [B, d]
    for l in range(cfg.n_layers):
        qs, ks, vs = [], [], []
        for j in range(w):
            h = ref.rmsnorm(xs[j], p[f"l{l}.ln1"])
            qs.append(ref.rope(
                _split_heads(h @ p[f"l{l}.wq"], cfg.n_heads), pos_w[:, j]))
            ks.append(ref.rope(
                _split_heads(h @ p[f"l{l}.wk"], cfg.n_heads), pos_w[:, j]))
            vs.append(_split_heads(h @ p[f"l{l}.wv"], cfg.n_heads))
        k_all = jnp.where(valid[..., None, None], jnp.stack(ks, axis=1), 0.0)
        v_all = jnp.where(valid[..., None, None], jnp.stack(vs, axis=1), 0.0)
        pool = pool.at[blk, l, 0, off].set(k_all)
        pool = pool.at[blk, l, 1, off].set(v_all)
        att = attn_k.paged_chunk_decode_attention(
            jnp.stack(qs, axis=1), pool[:, l, 0], pool[:, l, 1], table, pos_w
        )
        for j in range(w):
            xj = xs[j] + _merge_heads(att[:, j]) @ p[f"l{l}.wo"]
            h2 = ref.rmsnorm(xj, p[f"l{l}.ln2"])
            xs[j] = xj + jax.nn.gelu(h2 @ p[f"l{l}.w1"]) @ p[f"l{l}.w2"]
    x_last = xs[0]
    for j in range(1, w):
        x_last = jnp.where((lane[j] < vlen)[:, None], xs[j], x_last)
    next_tok, chosen_lp, lp_all, ent = _sample_head(
        cfg, p, x_last, gumbel, force_tok, force_mask, temp
    )
    return next_tok, chosen_lp, lp_all, pool, ent


# ---------------------------------------------------------------------------
# train (IS-REINFORCE + value baseline + fused Adam)
# ---------------------------------------------------------------------------

def _targets(tokens):
    """targets[t] = tokens[t+1]; last column PAD (mask must be 0 there)."""
    return jnp.concatenate(
        [tokens[:, 1:], jnp.full((tokens.shape[0], 1), vocab.PAD_ID, jnp.int32)],
        axis=1,
    )


def train_step(cfg, params, m, v, step, tokens, seg, pos, behavior_lp,
               adv_in, reward, mask, is_w, lr, clip_c, adv_mode, vf_coef,
               is_flag):
    """One optimizer step of Eq. (5) with truncated IS weights.

    adv_mode = 0: use adv_in (preprocessor group baseline, GRPO-style);
    adv_mode = 1: use R - v_phi (Eq. 4 learned value baseline, trained
    in the same step with coefficient vf_coef).

    is_flag selects the IS-weight source (`[rl] is_correction`):
      0 — uncorrected: every trained token weighs 1 (the ablation arm);
      1 — device truncated weights min(c, pi/mu) recomputed from the
          current policy's logprobs (the default; matches Eq. 5 exactly);
      2 — take the host-filled is_w lane verbatim (harness / replay runs
          that computed weights against a pinned scorer).

    reward is per-token [B, T] (constant across each packed segment) so
    that online sequence packing — multiple sequences per row — stays
    exact. Returns (params', m', v', metrics[10]) — METRIC_NAMES order.
    """
    targets = _targets(tokens)
    nm = jnp.sum(mask) + 1e-6

    def loss_fn(ps):
        h = forward_hidden(cfg, ps, tokens, seg, pos, use_pallas_attn=False)
        lp, w_dev, ent = loss_k.fused_loss(h, ps[0], targets, behavior_lp, clip_c)
        w = jnp.where(is_flag == 1.0, w_dev,
                      jnp.where(is_flag == 2.0, is_w, 1.0))
        values = h @ unpack(cfg, ps)["value_head"]           # [B, T]
        adv_value = reward - jax.lax.stop_gradient(values)
        adv_used = adv_mode * adv_value + (1.0 - adv_mode) * adv_in
        pg_loss = -jnp.sum(w * adv_used * lp * mask) / nm
        v_loss = jnp.sum(jnp.square(values - reward) * mask) / nm
        loss = pg_loss + vf_coef * v_loss
        aux = (pg_loss, v_loss, lp, w, ent)
        return loss, aux

    (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    pg_loss, v_loss, lp, w, ent = aux

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads))
    p2, m2, v2 = adam_k.adam_update_tree(params, m, v, grads, lr, step)

    # on-policyness metrics (Fig 6): masked ESS of the weights actually
    # applied (is_flag=0 therefore reports ESS 1), k3 KL estimator, clip
    # fraction. The rust trainer cross-checks ess against its host-side
    # oracle computed from the is_w lane (train/ess_host).
    sw = jnp.sum(w * mask)
    sw2 = jnp.sum(jnp.square(w) * mask)
    ess = jnp.square(sw) / (nm * sw2 + 1e-12)
    log_ratio = lp - behavior_lp
    ratio = jnp.exp(log_ratio)
    mean_kl = jnp.sum((ratio - 1.0 - log_ratio) * mask) / nm
    clip_frac = jnp.sum((ratio > clip_c).astype(jnp.float32) * mask) / nm
    entropy = jnp.sum(ent * mask) / nm
    mean_ratio = jnp.sum(ratio * mask) / nm

    metrics = jnp.stack([
        loss, pg_loss, v_loss, ess, mean_kl, clip_frac,
        gnorm, entropy, mean_ratio, jnp.sum(mask),
    ])
    return p2, m2, v2, metrics


def sft_step(cfg, params, m, v, step, tokens, seg, pos, mask, lr):
    """Cross-entropy warmup step (the pretraining stand-in). Reuses the
    fused loss kernel: CE gradient == REINFORCE gradient with w*adv == 1."""
    targets = _targets(tokens)
    nm = jnp.sum(mask) + 1e-6
    zeros = jnp.zeros_like(mask)

    def loss_fn(ps):
        h = forward_hidden(cfg, ps, tokens, seg, pos, use_pallas_attn=False)
        lp, _w, _ent = loss_k.fused_loss(h, ps[0], targets, zeros, jnp.float32(1.0))
        return -jnp.sum(lp * mask) / nm

    loss, grads = jax.value_and_grad(loss_fn)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads))
    p2, m2, v2 = adam_k.adam_update_tree(params, m, v, grads, lr, step)
    return p2, m2, v2, jnp.stack([loss, gnorm, jnp.sum(mask)])


# ---------------------------------------------------------------------------
# scoring (preprocessor / KL study)
# ---------------------------------------------------------------------------

def score(cfg, params, tokens, seg, pos):
    """Per-token logprobs under `params` (teacher forcing, Pallas flash
    attention + fused head). lp[t] refers to tokens[t+1]; lp[:, -1] = 0."""
    h = forward_hidden(cfg, params, tokens, seg, pos, use_pallas_attn=True)
    targets = _targets(tokens)
    zeros = jnp.zeros(tokens.shape, jnp.float32)
    lp, _w, ent = loss_k.fused_loss(h, params[0], targets, zeros, jnp.float32(1.0))
    lp = lp.at[:, -1].set(0.0)
    return lp, ent


def score_full(cfg, params, tokens, seg, pos):
    """score() plus the full per-position log-distribution [B, T, V]
    (Fig 7 needs full distributions for exact per-token KL)."""
    h = forward_hidden(cfg, params, tokens, seg, pos, use_pallas_attn=True)
    logits = h @ params[0].T
    logdist = jax.nn.log_softmax(logits, axis=-1)
    targets = _targets(tokens)
    lp = jnp.take_along_axis(logdist, targets[..., None], axis=-1)[..., 0]
    lp = lp.at[:, -1].set(0.0)
    return lp, logdist
