"""AOT exporter: lower every L2 graph to XLA HLO *text* artifacts.

Run once at build time (`make artifacts`); the rust runtime then loads and
compiles the text with `HloModuleProto::from_text_file` and never touches
python again.

HLO text — NOT `lowered.compile()` / serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (per variant v in {tiny, small, base}):
    artifacts/<v>_init.hlo.txt      seed -> params
    artifacts/<v>_decode.hlo.txt    engine decode step (dense KV layout)
    artifacts/<v>_decode_paged.hlo.txt  block-indexed decode step against
                                    the paged KV pool; both decode
                                    variants donate their cache operand
                                    (input_output_alias in the HLO text)
    artifacts/<v>_prefill_chunk.hlo.txt       W-token chunked prefill
    artifacts/<v>_prefill_chunk_paged.hlo.txt ... against the paged pool;
                                    both donate the cache like decode
    artifacts/<v>_train.hlo.txt     IS-REINFORCE + Adam optimizer step
    artifacts/<v>_sft.hlo.txt       cross-entropy warmup step
    artifacts/<v>_score.hlo.txt     per-token logprobs
    artifacts/<v>_score_full.hlo.txt  ... plus full log-distributions
    artifacts/manifest.json         dims, param specs, io signatures
    artifacts/vocab.json            id -> token table (rust cross-check)
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model, vocab


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    jdt = {"f32": jnp.float32, "i32": jnp.int32}[dtype]
    return jax.ShapeDtypeStruct(shape, jdt)


def graph_signatures(cfg: configs.ModelConfig):
    """Non-parameter runtime inputs of every graph, in call order.
    (name, shape, dtype) — the manifest records these for the rust side."""
    bg, bt = cfg.gen_batch, cfg.train_batch
    t, tm, v = cfg.seq_len, cfg.max_seq, cfg.vocab
    kv = model.kv_shape(cfg)
    pool = model.kv_pool_shape(cfg)
    nb = model.blocks_per_row(cfg)
    return {
        "init": [("seed", (), "i32")],
        "decode": [
            ("kv", kv, "f32"),
            ("pos", (bg,), "i32"),
            ("cur_tok", (bg,), "i32"),
            ("gumbel", (bg, v), "f32"),
            ("force_tok", (bg,), "i32"),
            ("force_mask", (bg,), "f32"),
            ("temp", (), "f32"),
        ],
        "decode_paged": [
            ("kv_pool", pool, "f32"),
            ("block_table", (bg, nb), "i32"),
            ("copy_src", (bg,), "i32"),
            ("copy_dst", (bg,), "i32"),
            ("pos", (bg,), "i32"),
            ("cur_tok", (bg,), "i32"),
            ("gumbel", (bg, v), "f32"),
            ("force_tok", (bg,), "i32"),
            ("force_mask", (bg,), "f32"),
            ("temp", (), "f32"),
        ],
        "prefill_chunk": [
            ("kv", kv, "f32"),
            ("start", (bg,), "i32"),
            ("chunk_toks", (bg, cfg.prefill_chunk), "i32"),
            ("vlen", (bg,), "i32"),
            ("gumbel", (bg, v), "f32"),
            ("force_tok", (bg,), "i32"),
            ("force_mask", (bg,), "f32"),
            ("temp", (), "f32"),
        ],
        "prefill_chunk_paged": [
            ("kv_pool", pool, "f32"),
            ("block_table", (bg, nb), "i32"),
            ("copy_src", (bg,), "i32"),
            ("copy_dst", (bg,), "i32"),
            ("start", (bg,), "i32"),
            ("chunk_toks", (bg, cfg.prefill_chunk), "i32"),
            ("vlen", (bg,), "i32"),
            ("gumbel", (bg, v), "f32"),
            ("force_tok", (bg,), "i32"),
            ("force_mask", (bg,), "f32"),
            ("temp", (), "f32"),
        ],
        "train": [
            ("step", (), "f32"),
            ("tokens", (bt, t), "i32"),
            ("seg", (bt, t), "i32"),
            ("pos", (bt, t), "i32"),
            ("behavior_lp", (bt, t), "f32"),
            ("adv_in", (bt, t), "f32"),
            ("reward", (bt, t), "f32"),
            ("mask", (bt, t), "f32"),
            ("is_w", (bt, t), "f32"),
            ("lr", (), "f32"),
            ("clip_c", (), "f32"),
            ("adv_mode", (), "f32"),
            ("vf_coef", (), "f32"),
            ("is_flag", (), "f32"),
        ],
        "sft": [
            ("step", (), "f32"),
            ("tokens", (bt, t), "i32"),
            ("seg", (bt, t), "i32"),
            ("pos", (bt, t), "i32"),
            ("mask", (bt, t), "f32"),
            ("lr", (), "f32"),
        ],
        "score": [
            ("tokens", (bt, t), "i32"),
            ("seg", (bt, t), "i32"),
            ("pos", (bt, t), "i32"),
        ],
        "score_full": [
            ("tokens", (bt, t), "i32"),
            ("seg", (bt, t), "i32"),
            ("pos", (bt, t), "i32"),
        ],
    }


def graph_fns(cfg: configs.ModelConfig):
    """graph name -> (callable, takes_opt_state). Parameter-list arguments
    always come first; opt-state graphs take (params, m, v, *rest)."""
    P = len(cfg.param_specs())

    def with_params(f, n_state):
        """Wrap f so the flat-literal calling convention (params unrolled)
        becomes the model.py list convention."""
        @functools.wraps(f)
        def g(*args):
            lists = []
            off = 0
            for _ in range(n_state):
                lists.append(list(args[off:off + P]))
                off += P
            return f(cfg, *lists, *args[off:])
        return g

    return {
        "init": (lambda seed: tuple(model.init_params(cfg, seed)), 0),
        "decode": (with_params(model.decode_step, 1), 1),
        "decode_paged": (with_params(model.decode_step_paged, 1), 1),
        "prefill_chunk": (with_params(model.prefill_chunk, 1), 1),
        "prefill_chunk_paged": (with_params(model.prefill_chunk_paged, 1), 1),
        "train": (with_params(model.train_step, 3), 3),
        "sft": (with_params(model.sft_step, 3), 3),
        "score": (with_params(model.score, 1), 1),
        "score_full": (with_params(model.score_full, 1), 1),
    }


# Donation plan: the decode and prefill-chunk variants update their cache
# operand (dense kv / paged pool — the first runtime input, flat argument
# index P = number of params) and return it at output tuple index 3
# (DECODE_KV_OUT on the rust side). donate_argnums survives the
# stablehlo -> HLO-text path as a real
# `input_output_alias={ {3}: (P, {}, may-alias) }` header, which is
# what lets PJRT satisfy the declared donation at `run_buffers_b` call
# sites with a true in-place update instead of a copy.
DONATED_CACHE_GRAPHS = ("decode", "decode_paged",
                        "prefill_chunk", "prefill_chunk_paged")
DECODE_KV_OUT = 3


def donation_plan(cfg: configs.ModelConfig, name: str):
    """(donate_argnums, alias record) for a graph; (None, None) if the
    graph donates nothing."""
    if name not in DONATED_CACHE_GRAPHS:
        return None, None
    P = len(cfg.param_specs())
    return (P,), {"param": P, "output": DECODE_KV_OUT}


def lower_variant(cfg: configs.ModelConfig, out_dir: str, only=None):
    sigs = graph_signatures(cfg)
    fns = graph_fns(cfg)
    params_specs = [
        _spec(shape) for _, shape in cfg.param_specs()
    ]
    files = {}
    for name, (fn, n_state) in fns.items():
        if only and name not in only:
            continue
        example = []
        for _ in range(n_state):
            example.extend(params_specs)
        for _, shape, dt in sigs[name]:
            example.append(_spec(shape, dt))
        # flatten output pytrees to a tuple of arrays for a stable rust ABI
        def flat_fn(*args, _fn=fn):
            out = _fn(*args)
            return tuple(jax.tree_util.tree_leaves(out))
        # keep_unused: graphs like decode never touch value_head, but the
        # rust ABI passes the full canonical param list to every graph.
        donate, _ = donation_plan(cfg, name)
        jitted = (
            jax.jit(flat_fn, keep_unused=True, donate_argnums=donate)
            if donate
            else jax.jit(flat_fn, keep_unused=True)
        )
        lowered = jitted.lower(*example)
        text = to_hlo_text(lowered)
        fname = f"{cfg.name}_{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[name] = fname
        print(f"  {fname}: {len(text) / 1e6:.2f} MB")
    return files


def build_manifest(variants, files_by_variant):
    out = {"variants": {}, "metric_names": model.METRIC_NAMES,
           "sft_metric_names": model.SFT_METRIC_NAMES,
           "pad_id": vocab.PAD_ID, "bos_id": vocab.BOS_ID,
           "eos_id": vocab.EOS_ID, "vocab_size": vocab.V}
    for cfg in variants:
        sigs = graph_signatures(cfg)
        out["variants"][cfg.name] = {
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "max_seq": cfg.max_seq,
            "gen_batch": cfg.gen_batch,
            "train_batch": cfg.train_batch,
            "seq_len": cfg.seq_len,
            "vocab": cfg.vocab,
            "n_params": cfg.n_params(),
            "kv_block_size": cfg.kv_block_size,
            "kv_blocks_per_row": model.blocks_per_row(cfg),
            # pool block count includes the trash block (last index)
            "kv_pool_blocks": model.kv_pool_shape(cfg)[0],
            # chunk width W baked into the prefill_chunk graphs; the rust
            # engine's `[kv] prefill_chunk` must be <= this
            "prefill_chunk": cfg.prefill_chunk,
            "aliases": {
                g: rec
                for g in sigs
                for rec in [donation_plan(cfg, g)[1]]
                if rec is not None
            },
            "params": [
                {"name": n, "shape": list(s)} for n, s in cfg.param_specs()
            ],
            "artifacts": files_by_variant[cfg.name],
            "inputs": {
                g: [{"name": n, "shape": list(s), "dtype": d}
                    for n, s, d in sig]
                for g, sig in sigs.items()
            },
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default="tiny,small,base")
    ap.add_argument("--graphs", default=None,
                    help="comma list to restrict (default: all)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.graphs.split(",")) if args.graphs else None
    variants = [configs.VARIANTS[n] for n in args.variants.split(",")]
    files = {}
    for cfg in variants:
        print(f"[aot] lowering variant {cfg.name} "
              f"({cfg.n_params() / 1e6:.2f}M params)")
        files[cfg.name] = lower_variant(cfg, args.out_dir, only)
    manifest = build_manifest(variants, files)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(args.out_dir, "vocab.json"), "w") as f:
        json.dump({"table": vocab.build_table(), "alphabet": vocab.ALPHABET},
                  f, indent=1)
    print("[aot] manifest + vocab written")


if __name__ == "__main__":
    main()
