"""Model-size variants shared by model.py, aot.py and the tests.

Each variant bakes every static dimension of the AOT artifacts: the rust
runtime cannot reshape a compiled executable, so the generation batch
(`gen_batch` = the engine's slot count H), the training batch/sequence
(`train_batch` x `seq_len`) and the KV capacity (`max_seq`) are all fixed
per artifact.  The rust manifest (artifacts/manifest.json) records them.

Sizing rationale (DESIGN.md §2): the testbed is a single CPU core, so the
"base" variant (~3M params) plays the role of the paper's Qwen-2.5-7B.
Dynamics of mixed-policy lag / ESS / IS-truncation do not depend on model
scale; throughput-at-scale figures come from perfmodel/simcluster instead.
"""

from dataclasses import dataclass, field

from . import vocab


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    max_seq: int      # KV-cache capacity per generation slot
    gen_batch: int    # engine slots per actor (paper's H)
    train_batch: int  # optimizer batch rows (packed)
    seq_len: int      # packed training sequence length
    vocab: int = vocab.V
    # Physical KV page size (tokens per device block) for the paged decode
    # graph. Must divide max_seq so the block-gathered view is exactly the
    # dense [max_seq] timeline — that equality is what makes the paged
    # kernel bit-identical to the dense one (tests/test_model.py).
    kv_block_size: int = 16
    # Chunk width W of the prefill_chunk graphs: forced tokens ingested per
    # dispatch during prompt prefill and KV replay (ceil(P/W) dispatches
    # for a P-token prefix instead of P). Baked into the artifact like
    # every other dimension; the rust engine's `[kv] prefill_chunk` must
    # not exceed it (shorter chunks ride the graph with a parked tail).
    prefill_chunk: int = 8

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def ffn_dim(self) -> int:
        return 4 * self.d_model

    def param_specs(self):
        """Canonical flat parameter ordering. Mirrored in rust via manifest."""
        d, f, v = self.d_model, self.ffn_dim, self.vocab
        specs = [
            ("embed", (v, d)),
            ("final_norm", (d,)),
            ("value_head", (d,)),
        ]
        for l in range(self.n_layers):
            specs += [
                (f"l{l}.wq", (d, d)),
                (f"l{l}.wk", (d, d)),
                (f"l{l}.wv", (d, d)),
                (f"l{l}.wo", (d, d)),
                (f"l{l}.w1", (d, f)),
                (f"l{l}.w2", (f, d)),
                (f"l{l}.ln1", (d,)),
                (f"l{l}.ln2", (d,)),
            ]
        return specs

    def n_params(self) -> int:
        import math
        return sum(math.prod(s) for _, s in self.param_specs())


TINY = ModelConfig(
    name="tiny", d_model=32, n_layers=2, n_heads=2,
    max_seq=96, gen_batch=4, train_batch=4, seq_len=96,
)

SMALL = ModelConfig(
    name="small", d_model=64, n_layers=3, n_heads=4,
    max_seq=160, gen_batch=8, train_batch=8, seq_len=160,
)

BASE = ModelConfig(
    name="base", d_model=128, n_layers=4, n_heads=4,
    max_seq=224, gen_batch=16, train_batch=16, seq_len=224,
)

VARIANTS = {c.name: c for c in (TINY, SMALL, BASE)}
