"""Shared vocabulary between the python compile path and the rust runtime.

The tokenizer is character-level over a small fixed alphabet that covers the
synthetic arithmetic-reasoning tasks (the OpenReasoner-Zero stand-in, see
DESIGN.md §2).  The rust side (rust/src/model/tokenizer.rs) mirrors this
table; `aot.py` dumps it to artifacts/vocab.json and a cargo test
cross-checks the two, so they can never drift silently.

Token ids:
    0  PAD      padding (never predicted, masked out of every loss)
    1  BOS      beginning of sequence
    2  EOS      end of sequence (generation stops here)
    3+ printable characters from `ALPHABET`, in order.

`V` is padded to 64 so the logits matmul hits MXU-friendly shapes; the
trailing ids are unused and their logits are forced to -inf nowhere — the
model simply learns to never produce them (they never appear in data).
"""

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2

# Order is load-bearing: rust/src/model/tokenizer.rs mirrors it.
ALPHABET = "0123456789+-*/=()<>.,:; \nabcdefghijklmnopqrstuvwxyz?_"

V = 64  # padded vocab size

SPECIALS = ["<pad>", "<bos>", "<eos>"]


def build_table():
    """id -> token string (specials as <...>), padded to V with <unused-i>."""
    table = list(SPECIALS) + [c for c in ALPHABET]
    assert len(table) <= V, f"alphabet too large: {len(table)} > {V}"
    while len(table) < V:
        table.append(f"<unused{len(table)}>")
    return table


def encode(text: str):
    base = len(SPECIALS)
    idx = {c: base + i for i, c in enumerate(ALPHABET)}
    return [idx[c] for c in text]


def decode(ids):
    table = build_table()
    out = []
    for i in ids:
        if i == EOS_ID:
            break
        if i in (PAD_ID, BOS_ID):
            continue
        tok = table[i]
        if not tok.startswith("<"):
            out.append(tok)
    return "".join(out)
