"""Fused IS-REINFORCE head + loss Pallas kernel (L1), with custom VJP.

This is the training hot-spot of the paper's Eq. (5): for every target
token it computes, in one VMEM-resident tile pass,

    logits  = h @ E^T                 (tied softmax head, MXU matmul)
    lp      = log_softmax(logits)[y]  (current-policy logprob)
    ratio   = exp(lp - behavior_lp)   (importance ratio vs recorded mu)
    w       = min(ratio, c)           (truncated IS weight, Eq. 5)
    ent     = entropy(softmax(logits))

without materializing the [B, T, V] logits tensor in HBM — each grid step
holds a [B, T_BLOCK, V] tile (batch vectorized in the body; the grid
walks time tiles only — see attention.py for the grid-shape rationale).
The IS weight `w` is a stop-gradient coefficient (Eq. 5 weights the
*gradient*), so the backward pass is
d logits = dlp * (onehot(y) - softmax(logits)), recomputed tile-by-tile
(activation recompute: the fwd saves only h/E/targets, not logits).

The custom_vjp backward is itself a Pallas kernel that accumulates dE
across grid steps into a single output block (sequential grid semantics).
pytest checks both fwd (vs ref.fused_loss_fwd) and bwd (vs jax.grad of the
reference).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

T_BLOCK = 32


def _fwd_kernel(h_ref, e_ref, tgt_ref, blp_ref, c_ref, lp_ref, w_ref, ent_ref):
    """One time-tile grid step, vectorized over batch.
    h [B,bt,d]; e [V,d]; tgt/blp [B,bt]; c [1]."""
    h = h_ref[...].astype(jnp.float32)                  # [B, bt, d]
    e = e_ref[...].astype(jnp.float32)                  # [V, d]
    logits = jnp.einsum("btd,vd->btv", h, e)            # [B, bt, V]
    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    lp_all = logits - lse[..., None]
    tgt = tgt_ref[...]
    onehot = jax.lax.iota(jnp.int32, e.shape[0])[None, None, :] == tgt[..., None]
    lp = jnp.sum(jnp.where(onehot, lp_all, 0.0), axis=-1)
    ratio = jnp.exp(lp - blp_ref[...])
    w = jnp.minimum(ratio, c_ref[0])
    p = jnp.exp(lp_all)
    ent = -jnp.sum(p * lp_all, axis=-1)
    lp_ref[...] = lp
    w_ref[...] = w
    ent_ref[...] = ent


def _bwd_kernel(h_ref, e_ref, tgt_ref, dlp_ref, dh_ref, de_ref):
    """Backward grid step: recompute the logits tile, emit dh and
    accumulate dE. The dE block is shared by every grid step."""
    ti = pl.program_id(0)
    h = h_ref[...].astype(jnp.float32)                  # [B, bt, d]
    e = e_ref[...].astype(jnp.float32)                  # [V, d]
    logits = jnp.einsum("btd,vd->btv", h, e)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)          # softmax [B, bt, V]
    tgt = tgt_ref[...]
    onehot = (
        jax.lax.iota(jnp.int32, e.shape[0])[None, None, :] == tgt[..., None]
    ).astype(jnp.float32)
    dlogits = dlp_ref[...][..., None] * (onehot - p)    # [B, bt, V]
    dh_ref[...] = jnp.einsum("btv,vd->btd", dlogits, e).astype(dh_ref.dtype)

    @pl.when(ti == 0)
    def _init():
        de_ref[...] = jnp.zeros_like(de_ref)

    de_ref[...] += jnp.einsum("btv,btd->vd", dlogits, h).astype(de_ref.dtype)


def _fused_loss_fwd_impl(h, embed, targets, behavior_lp, clip_c):
    b, t, d = h.shape
    v = embed.shape[0]
    assert t % T_BLOCK == 0, (t, T_BLOCK)
    grid = (t // T_BLOCK,)
    c_arr = jnp.reshape(clip_c.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, T_BLOCK, d), lambda ti: (0, ti, 0)),
            pl.BlockSpec((v, d), lambda ti: (0, 0)),
            pl.BlockSpec((b, T_BLOCK), lambda ti: (0, ti)),
            pl.BlockSpec((b, T_BLOCK), lambda ti: (0, ti)),
            pl.BlockSpec((1,), lambda ti: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((b, T_BLOCK), lambda ti: (0, ti)),
            pl.BlockSpec((b, T_BLOCK), lambda ti: (0, ti)),
            pl.BlockSpec((b, T_BLOCK), lambda ti: (0, ti)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t), jnp.float32),
            jax.ShapeDtypeStruct((b, t), jnp.float32),
            jax.ShapeDtypeStruct((b, t), jnp.float32),
        ],
        interpret=True,
    )(h, embed, targets, behavior_lp, c_arr)


def _fused_loss_bwd_impl(h, embed, targets, dlp):
    b, t, d = h.shape
    v = embed.shape[0]
    grid = (t // T_BLOCK,)
    return pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, T_BLOCK, d), lambda ti: (0, ti, 0)),
            pl.BlockSpec((v, d), lambda ti: (0, 0)),
            pl.BlockSpec((b, T_BLOCK), lambda ti: (0, ti)),
            pl.BlockSpec((b, T_BLOCK), lambda ti: (0, ti)),
        ],
        out_specs=[
            pl.BlockSpec((b, T_BLOCK, d), lambda ti: (0, ti, 0)),
            pl.BlockSpec((v, d), lambda ti: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, d), h.dtype),
            jax.ShapeDtypeStruct((v, d), embed.dtype),
        ],
        interpret=True,
    )(h, embed, targets, dlp)


@jax.custom_vjp
def fused_loss(h, embed, targets, behavior_lp, clip_c):
    """Returns (lp, w, ent) — see module docstring. Differentiable in
    (h, embed) through lp only; w and ent are stop-grad outputs."""
    return _fused_loss_fwd_impl(h, embed, targets, behavior_lp, clip_c)


def _vjp_fwd(h, embed, targets, behavior_lp, clip_c):
    out = _fused_loss_fwd_impl(h, embed, targets, behavior_lp, clip_c)
    return out, (h, embed, targets)


def _vjp_bwd(res, cotangents):
    h, embed, targets = res
    dlp, _dw, _dent = cotangents  # w/ent are stop-grad: cotangents dropped
    dh, de = _fused_loss_bwd_impl(h, embed, targets, dlp)
    return dh, de, None, None, None


fused_loss.defvjp(_vjp_fwd, _vjp_bwd)
