"""Pure-jnp reference oracles for every Pallas kernel.

These are the CORE correctness signal: pytest (+ hypothesis sweeps) asserts
`kernels.* ≈ ref.*` over shapes/dtypes/seeds. The train graph's backward
pass is additionally checked against jax.grad of the reference loss.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope(x, positions, base=10000.0):
    """Rotary embedding. x: [..., n_heads, head_dim], positions: int32 array
    matching x's leading dims (one position per token)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def causal_segment_attention(q, k, v, seg):
    """Full (prefill / teacher-forcing) attention.

    q,k,v: [B, T, H, D] (already rope'd); seg: [B, T] int32 segment ids
    (0 = padding; packing restarts segments).
    mask[i,j] = causal(j<=i) AND seg[i]==seg[j] AND seg[j] != 0.
    """
    b, t, h, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("bihd,bjhd->bhij", q, k) * scale
    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    causal = j <= i                                        # [T, T]
    same = seg[:, :, None] == seg[:, None, :]              # [B, T, T]
    valid = (seg[:, None, :] != 0) & same & causal[None]
    logits = jnp.where(valid[:, None, :, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    # rows with no valid key (padding queries) -> zero output
    any_valid = jnp.any(valid, axis=-1)                    # [B, T]
    out = jnp.einsum("bhij,bjhd->bihd", p, v)
    return jnp.where(any_valid[:, :, None, None], out, 0.0)


def decode_attention(q, k_cache, v_cache, pos):
    """Single-step attention against a per-slot dense KV cache.

    q: [B, H, D] (rope'd query at position pos[b]);
    k_cache, v_cache: [B, T, H, D]; pos: [B] int32 — attends to 0..=pos[b].
    """
    b, t, h, d = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("bhd,bjhd->bhj", q, k_cache) * scale
    valid = jnp.arange(t)[None, :] <= pos[:, None]         # [B, T]
    logits = jnp.where(valid[:, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhj,bjhd->bhd", p, v_cache)


def chunk_decode_attention(q, k_cache, v_cache, pos):
    """Chunked-prefill attention: W queries per row against the dense cache.

    q: [B, W, H, D] (rope'd queries; lane j of row b sits at cache position
    pos[b, j]); k_cache, v_cache: [B, T, H, D]; pos: [B, W] int32 — lane j
    attends to cache positions 0..=pos[b, j]. Within-chunk causality falls
    out of the position mask because the caller scatters all W keys before
    attending. Invalid (parked) lanes carry pos = T-1, so their softmax is
    finite; their output is garbage by contract and never read.
    """
    b, t, h, d = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("bwhd,bjhd->bhwj", q, k_cache) * scale
    valid = jnp.arange(t)[None, None, :] <= pos[:, :, None]  # [B, W, T]
    logits = jnp.where(valid[:, None, :, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhwj,bjhd->bwhd", p, v_cache)


def gather_kv_blocks(pool_plane, table):
    """Densify one K or V pool plane through a block table.

    pool_plane: [N, bs, H, D] (all physical blocks of one layer/plane);
    table: [B, NB] int32 — logical block j of row b is physical block
    table[b, j]. Returns the dense per-row view [B, NB*bs, H, D] where
    index i along the time axis is logical position i.
    """
    b, nb = table.shape
    g = pool_plane[table]                                  # [B, NB, bs, H, D]
    return g.reshape(b, nb * g.shape[2], *g.shape[3:])


def paged_decode_attention(q, k_pool, v_pool, table, pos):
    """Oracle for kernels.attention.paged_decode_attention: densify the
    pool through the table, then it IS dense decode attention."""
    return decode_attention(
        q, gather_kv_blocks(k_pool, table), gather_kv_blocks(v_pool, table), pos
    )


def paged_chunk_decode_attention(q, k_pool, v_pool, table, pos):
    """Oracle for kernels.attention.paged_chunk_decode_attention: densify
    the pool through the table, then it IS dense chunk attention."""
    return chunk_decode_attention(
        q, gather_kv_blocks(k_pool, table), gather_kv_blocks(v_pool, table), pos
    )


def fused_loss_fwd(h, embed, targets, behavior_lp, clip_c):
    """Reference for the fused IS-REINFORCE head+loss kernel (forward).

    h: [B, T, D] final hidden states (already final-norm'ed);
    embed: [V, D] tied softmax head; targets: [B, T] int32;
    behavior_lp: [B, T] behavior-policy logprob of the target token.

    Returns (lp, w, ent):
      lp  [B,T] current-policy logprob of the target token (differentiable)
      w   [B,T] truncated IS weight min(c, exp(lp - behavior_lp)) (stop-grad)
      ent [B,T] policy entropy at each position (stop-grad, metrics only)
    """
    logits = jnp.einsum("btd,vd->btv", h, embed)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lp_all = logits - lse[..., None]
    lp = jnp.take_along_axis(lp_all, targets[..., None], axis=-1)[..., 0]
    ratio = jnp.exp(lp - behavior_lp)
    w = jnp.minimum(ratio, clip_c)
    p = jnp.exp(lp_all)
    ent = -jnp.sum(p * lp_all, axis=-1)
    return lp, jax.lax.stop_gradient(w), jax.lax.stop_gradient(ent)


def adam_update(p, m, v, g, lr, beta1, beta2, eps, step):
    """Reference fused Adam (bias-corrected). step is the 1-based step."""
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * jnp.square(g)
    mhat = m2 / (1.0 - beta1**step)
    vhat = v2 / (1.0 - beta2**step)
    p2 = p - lr * mhat / (jnp.sqrt(vhat) + eps)
    return p2, m2, v2
