"""Pallas attention kernels (L1).

Two kernels, matching the two execution regimes of the generation engine
and trainer (DESIGN.md §3):

* `flash_attention` — tiled causal+segment attention over a full packed
  sequence (teacher forcing: the `score`/`score_full` artifacts and the
  KL-replay path). Flash-style schedule: the grid walks (head, q-tile);
  the batch dimension is vectorized *inside* the kernel body, K/V for the
  head are staged through VMEM and consumed in k-tiles with a
  running-softmax accumulator, so the [T, T] logits matrix never
  materializes.

* `decode_attention` — single-query attention against the dense per-slot
  KV cache, the per-token hot op of the engine's decode loop. Grid walks
  heads only; all slots are processed vectorized per grid step.

* `paged_decode_attention` — the same single-query op against a shared
  device *block pool* addressed through a per-row block table (vLLM-style
  paged KV). Each grid step gathers the row's blocks from the pool into a
  dense [B, T] timeline and then runs *exactly* `_decode_kernel`'s math,
  so paged output is bit-identical to dense whenever the gathered values
  match — the allocator's prefix sharing and CoW forks govern physical
  memory without touching numerics.

* `chunk_decode_attention` / `paged_chunk_decode_attention` — the W-query
  generalization backing the `prefill_chunk` graphs: lane j of row b is a
  query at cache position pos[b, j], masked to keys 0..=pos[b, j]. The
  caller scatters all W fresh K/V lanes *before* attending, so the
  position mask alone yields causal within-chunk + past-KV attention.
  Parked/invalid lanes ride along at pos = T-1 (full mask, finite
  softmax, output discarded). The paged variant gathers-then-denses like
  `_paged_decode_kernel`, inheriting the same bit-parity argument.

Grid-shape rationale (§Perf): batch-vectorized bodies keep the VMEM
footprint per grid step modest (≤ ~2 MiB at the base variant — table in
EXPERIMENTS.md §Perf) while minimizing the *number* of grid steps, which
matters twice: on real TPU fewer grid steps amortize the MXU pipeline
fill, and under `interpret=True` (the CPU correctness path — the Mosaic
lowering cannot run on CPU PJRT) every grid step pays interpreter
overhead — the original (batch, head) grid made the decode hot loop ~12x
slower end-to-end.

Hardware adaptation (paper targets CUDA/vLLM paged attention): the
BlockSpec index maps express the HBM->VMEM schedule that vLLM expresses
with thread-block tiling; see DESIGN.md §Hardware-Adaptation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

Q_BLOCK = 32  # divides every variant's seq_len (96, 160, 224)
K_BLOCK = 32


def _flash_kernel(q_ref, k_ref, v_ref, segq_ref, segk_ref, o_ref, *, scale, t_total):
    """One (head, q-tile) grid step, vectorized over batch. Shapes inside:
    q [B, bq, 1, hd]; k,v [B, T, 1, hd]; segq [B, bq]; segk [B, T]."""
    qi = pl.program_id(1)
    bq = q_ref.shape[1]
    hd = q_ref.shape[3]
    bsz = q_ref.shape[0]
    q = q_ref[:, :, 0, :].astype(jnp.float32)          # [B, bq, hd]
    seg_q = segq_ref[...]                              # [B, bq]
    row_ids = qi * bq + jax.lax.iota(jnp.int32, bq)    # global q positions

    n_kb = t_total // K_BLOCK

    def body(kb, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(
            k_ref[:, :, 0, :], (0, kb * K_BLOCK, 0), (bsz, K_BLOCK, hd)
        ).astype(jnp.float32)
        v = jax.lax.dynamic_slice(
            v_ref[:, :, 0, :], (0, kb * K_BLOCK, 0), (bsz, K_BLOCK, hd)
        ).astype(jnp.float32)
        seg_k = jax.lax.dynamic_slice(
            segk_ref[...], (0, kb * K_BLOCK), (bsz, K_BLOCK)
        )
        col_ids = kb * K_BLOCK + jax.lax.iota(jnp.int32, K_BLOCK)
        s = jnp.einsum("bqd,bkd->bqk", q, k) * scale   # [B, bq, K_BLOCK]
        valid = (
            (col_ids[None, None, :] <= row_ids[None, :, None])
            & (seg_q[:, :, None] == seg_k[:, None, :])
            & (seg_k[:, None, :] != 0)
        )
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(valid, p, 0.0)                   # masked rows stay inert
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bqk,bkd->bqd", p, v)
        return m_new, l_new, acc_new

    m0 = jnp.full((bsz, bq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bsz, bq), dtype=jnp.float32)
    a0 = jnp.zeros((bsz, bq, hd), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, a0))
    safe_l = jnp.where(l > 0.0, l, 1.0)
    out = jnp.where((l > 0.0)[..., None], acc / safe_l[..., None], 0.0)
    o_ref[:, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, seg):
    """q,k,v: [B, T, H, D] (rope already applied); seg: [B, T] int32.
    Equivalent to ref.causal_segment_attention."""
    b, t, h, d = q.shape
    assert t % Q_BLOCK == 0 and t % K_BLOCK == 0, (t, Q_BLOCK)
    scale = 1.0 / (d ** 0.5)
    grid = (h, t // Q_BLOCK)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, t_total=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, Q_BLOCK, 1, d), lambda hi, qi: (0, qi, hi, 0)),
            pl.BlockSpec((b, t, 1, d), lambda hi, qi: (0, 0, hi, 0)),
            pl.BlockSpec((b, t, 1, d), lambda hi, qi: (0, 0, hi, 0)),
            pl.BlockSpec((b, Q_BLOCK), lambda hi, qi: (0, qi)),
            pl.BlockSpec((b, t), lambda hi, qi: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, Q_BLOCK, 1, d), lambda hi, qi: (0, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, h, d), q.dtype),
        interpret=True,
    )(q, k, v, seg, seg)


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, *, scale):
    """One head per grid step, vectorized over slots.
    q [B,1,hd]; k,v [B,T,1,hd]; pos [B]."""
    t = k_ref.shape[1]
    q = q_ref[:, 0, :].astype(jnp.float32)             # [B, hd]
    k = k_ref[:, :, 0, :].astype(jnp.float32)          # [B, T, hd]
    v = v_ref[:, :, 0, :].astype(jnp.float32)
    s = jnp.einsum("bd,btd->bt", q, k) * scale         # [B, T]
    valid = jax.lax.iota(jnp.int32, t)[None, :] <= pos_ref[...][:, None]
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid, p, 0.0)
    out = jnp.einsum("bt,btd->bd", p, v) / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[:, 0, :] = out.astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, pos):
    """q: [B, H, D]; k_cache, v_cache: [B, T, H, D]; pos: [B] int32.
    Equivalent to ref.decode_attention."""
    b, t, h, d = k_cache.shape
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((b, 1, d), lambda hi: (0, hi, 0)),
            pl.BlockSpec((b, t, 1, d), lambda hi: (0, 0, hi, 0)),
            pl.BlockSpec((b, t, 1, d), lambda hi: (0, 0, hi, 0)),
            pl.BlockSpec((b,), lambda hi: (0,)),
        ],
        out_specs=pl.BlockSpec((b, 1, d), lambda hi: (0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=True,
    )(q, k_cache, v_cache, pos)


def _chunk_decode_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, *, scale):
    """One head per grid step, vectorized over slots; W query lanes per
    row. q [B,W,1,hd]; k,v [B,T,1,hd]; pos [B,W] (per-lane cache pos).

    Lanes are unrolled with byte-for-byte `_decode_kernel` math instead
    of one [B,W,T] einsum: XLA CPU contractions are not bit-stable across
    an extra batch dimension, and the parity contract (a chunk == the
    same tokens fed one step at a time) demands exact equality. K/V for
    the head are still staged once per grid step and shared by all lanes
    — the dispatch-count win is untouched.
    """
    t = k_ref.shape[1]
    w = q_ref.shape[1]
    k = k_ref[:, :, 0, :].astype(jnp.float32)          # [B, T, hd]
    v = v_ref[:, :, 0, :].astype(jnp.float32)
    pos = pos_ref[...]                                 # [B, W]
    for j in range(w):
        q = q_ref[:, j, 0, :].astype(jnp.float32)      # [B, hd]
        s = jnp.einsum("bd,btd->bt", q, k) * scale     # [B, T]
        valid = jax.lax.iota(jnp.int32, t)[None, :] <= pos[:, j][:, None]
        s = jnp.where(valid, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        p = jnp.where(valid, p, 0.0)
        out = jnp.einsum("bt,btd->bd", p, v) / jnp.sum(p, axis=-1, keepdims=True)
        o_ref[:, j, 0, :] = out.astype(o_ref.dtype)


def chunk_decode_attention(q, k_cache, v_cache, pos):
    """q: [B, W, H, D]; k_cache, v_cache: [B, T, H, D]; pos: [B, W] int32.
    Equivalent to ref.chunk_decode_attention."""
    b, t, h, d = k_cache.shape
    w = q.shape[1]
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_chunk_decode_kernel, scale=scale),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((b, w, 1, d), lambda hi: (0, 0, hi, 0)),
            pl.BlockSpec((b, t, 1, d), lambda hi: (0, 0, hi, 0)),
            pl.BlockSpec((b, t, 1, d), lambda hi: (0, 0, hi, 0)),
            pl.BlockSpec((b, w), lambda hi: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, w, 1, d), lambda hi: (0, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, w, h, d), q.dtype),
        interpret=True,
    )(q, k_cache, v_cache, pos)


def _paged_decode_kernel(q_ref, k_ref, v_ref, tbl_ref, pos_ref, o_ref, *, scale):
    """One head per grid step, vectorized over slots.
    q [B,1,hd]; k,v pool planes [N,bs,1,hd]; tbl [B,NB]; pos [B].

    Gather-then-dense: `k[tbl]` pulls each row's blocks into a contiguous
    [B, NB*bs, hd] timeline where gathered index i IS logical position i
    (block i//bs, offset i%bs). From there the math is byte-for-byte
    `_decode_kernel` — the proof obligation for dense/paged bit parity.
    Entries past pos[b] (unwritten tail, trash-block garbage) are masked
    exactly like the dense kernel masks its unwritten tail.
    """
    bs = k_ref.shape[1]
    q = q_ref[:, 0, :].astype(jnp.float32)             # [B, hd]
    tbl = tbl_ref[...]                                 # [B, NB]
    b, nb = tbl.shape
    t = nb * bs
    k = k_ref[:, :, 0, :].astype(jnp.float32)[tbl].reshape(b, t, -1)
    v = v_ref[:, :, 0, :].astype(jnp.float32)[tbl].reshape(b, t, -1)
    s = jnp.einsum("bd,btd->bt", q, k) * scale         # [B, T]
    valid = jax.lax.iota(jnp.int32, t)[None, :] <= pos_ref[...][:, None]
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid, p, 0.0)
    out = jnp.einsum("bt,btd->bd", p, v) / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[:, 0, :] = out.astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, table, pos):
    """q: [B, H, D]; k_pool, v_pool: [N, bs, H, D] (one layer/plane of the
    device block pool); table: [B, NB] int32 physical block ids (logical
    block j of row b lives at table[b, j]); pos: [B] int32.

    Equivalent to ref.paged_decode_attention, and bit-identical to
    decode_attention on the densified cache when NB*bs == max_seq.
    """
    n, bs, h, d = k_pool.shape
    b, nb = table.shape
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=scale),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((b, 1, d), lambda hi: (0, hi, 0)),
            pl.BlockSpec((n, bs, 1, d), lambda hi: (0, 0, hi, 0)),
            pl.BlockSpec((n, bs, 1, d), lambda hi: (0, 0, hi, 0)),
            pl.BlockSpec((b, nb), lambda hi: (0, 0)),
            pl.BlockSpec((b,), lambda hi: (0,)),
        ],
        out_specs=pl.BlockSpec((b, 1, d), lambda hi: (0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=True,
    )(q, k_pool, v_pool, table, pos)


def _paged_chunk_decode_kernel(q_ref, k_ref, v_ref, tbl_ref, pos_ref, o_ref, *, scale):
    """One head per grid step, vectorized over slots and chunk lanes.
    q [B,W,1,hd]; k,v pool planes [N,bs,1,hd]; tbl [B,NB]; pos [B,W].

    Gather-then-dense exactly like `_paged_decode_kernel`, then the math
    is byte-for-byte `_chunk_decode_kernel` — the same bit-parity proof
    obligation, now for W queries per row. The gather runs once per grid
    step; lanes share the densified timeline.
    """
    bs = k_ref.shape[1]
    w = q_ref.shape[1]
    tbl = tbl_ref[...]                                 # [B, NB]
    b, nb = tbl.shape
    t = nb * bs
    k = k_ref[:, :, 0, :].astype(jnp.float32)[tbl].reshape(b, t, -1)
    v = v_ref[:, :, 0, :].astype(jnp.float32)[tbl].reshape(b, t, -1)
    pos = pos_ref[...]                                 # [B, W]
    for j in range(w):
        q = q_ref[:, j, 0, :].astype(jnp.float32)      # [B, hd]
        s = jnp.einsum("bd,btd->bt", q, k) * scale     # [B, T]
        valid = jax.lax.iota(jnp.int32, t)[None, :] <= pos[:, j][:, None]
        s = jnp.where(valid, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        p = jnp.where(valid, p, 0.0)
        out = jnp.einsum("bt,btd->bd", p, v) / jnp.sum(p, axis=-1, keepdims=True)
        o_ref[:, j, 0, :] = out.astype(o_ref.dtype)


def paged_chunk_decode_attention(q, k_pool, v_pool, table, pos):
    """q: [B, W, H, D]; k_pool, v_pool: [N, bs, H, D]; table: [B, NB]
    int32; pos: [B, W] int32 per-lane cache positions.

    Equivalent to ref.paged_chunk_decode_attention, and bit-identical to
    chunk_decode_attention on the densified cache when NB*bs == max_seq.
    """
    n, bs, h, d = k_pool.shape
    b, nb = table.shape
    w = q.shape[1]
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_paged_chunk_decode_kernel, scale=scale),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((b, w, 1, d), lambda hi: (0, 0, hi, 0)),
            pl.BlockSpec((n, bs, 1, d), lambda hi: (0, 0, hi, 0)),
            pl.BlockSpec((n, bs, 1, d), lambda hi: (0, 0, hi, 0)),
            pl.BlockSpec((b, nb), lambda hi: (0, 0)),
            pl.BlockSpec((b, w), lambda hi: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, w, 1, d), lambda hi: (0, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, w, h, d), q.dtype),
        interpret=True,
    )(q, k_pool, v_pool, table, pos)
