"""Fused Adam update as a Pallas kernel (L1).

The paper's trainer (DeepSpeed) runs a fused Adam CUDA kernel; this is the
TPU-flavoured equivalent: each parameter tensor is flattened, padded to a
VMEM-friendly block multiple and updated in a single elementwise pass
(p, m, v, g -> p', m', v'), with bias correction computed from the
(runtime) step input.  beta/eps are compile-time constants; lr and step
are runtime scalars so the trainer can schedule the learning rate without
recompiling the artifact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192  # fewer grid steps: one per 8k elements (interpret overhead, §Perf)

BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8


def _adam_kernel(p_ref, m_ref, v_ref, g_ref, lr_ref, step_ref,
                 p2_ref, m2_ref, v2_ref):
    p = p_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    g = g_ref[...]
    lr = lr_ref[0]
    step = step_ref[0]
    m2 = BETA1 * m + (1.0 - BETA1) * g
    v2 = BETA2 * v + (1.0 - BETA2) * g * g
    c1 = 1.0 - jnp.exp(step * jnp.log(BETA1))
    c2 = 1.0 - jnp.exp(step * jnp.log(BETA2))
    mhat = m2 / c1
    vhat = v2 / c2
    p2_ref[...] = p - lr * mhat / (jnp.sqrt(vhat) + EPS)
    m2_ref[...] = m2
    v2_ref[...] = v2


def adam_update_flat(p, m, v, g, lr, step):
    """All of p/m/v/g are 1-D f32 of identical length (already padded to a
    BLOCK multiple by the caller). lr/step are scalars (step is 1-based)."""
    n = p.shape[0]
    assert n % BLOCK == 0, n
    lr_arr = jnp.reshape(lr.astype(jnp.float32), (1,))
    step_arr = jnp.reshape(step.astype(jnp.float32), (1,))
    grid = (n // BLOCK,)
    blk = pl.BlockSpec((BLOCK,), lambda i: (i,))
    scl = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[blk, blk, blk, blk, scl, scl],
        out_specs=[blk, blk, blk],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=True,
    )(p, m, v, g, lr_arr, step_arr)


def adam_update(p, m, v, g, lr, step):
    """Arbitrary-shape wrapper: flatten -> pad -> kernel -> unpad -> reshape.
    Matches ref.adam_update."""
    shape = p.shape
    n = p.size
    pad = (-n) % BLOCK
    flat = lambda x: jnp.pad(jnp.ravel(x.astype(jnp.float32)), (0, pad))
    p2, m2, v2 = adam_update_flat(flat(p), flat(m), flat(v), flat(g), lr, step)
    unflat = lambda x: jnp.reshape(x[:n], shape)
    return unflat(p2), unflat(m2), unflat(v2)


def adam_update_tree(params, ms, vs, grads, lr, step):
    """Apply the fused update across a list-of-arrays parameter set.

    All tensors are flattened and concatenated so the whole optimizer
    update is ONE pallas_call (one DeepSpeed-style fused kernel launch)
    instead of one per parameter — under interpret=True the per-call
    overhead of ~35 separate calls dominated the train step (§Perf).
    """
    sizes = [p.size for p in params]
    shapes = [p.shape for p in params]
    cat = lambda xs: jnp.concatenate(
        [jnp.ravel(x).astype(jnp.float32) for x in xs]
    )
    n = sum(sizes)
    pad = (-n) % BLOCK
    padded = lambda x: jnp.pad(cat(x), (0, pad))
    p2, m2, v2 = adam_update_flat(
        padded(params), padded(ms), padded(vs), padded(grads), lr, step
    )

    def split(flat):
        out = []
        off = 0
        for size, shape in zip(sizes, shapes):
            out.append(jnp.reshape(flat[off : off + size], shape))
            off += size
        return out

    return split(p2), split(m2), split(v2)
