"""L2 model invariants: causality, packing isolation, decode/score
consistency, training-step behaviour. All on the tiny config."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, vocab

CFG = configs.TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, 0)


def mk_tokens(seed, rows, fill):
    """Simple single-segment rows: BOS + `fill` random alphabet tokens."""
    t = CFG.seq_len
    key = jax.random.PRNGKey(seed)
    body = jax.random.randint(key, (rows, fill), 3, 40)
    tokens = jnp.zeros((rows, t), jnp.int32)
    tokens = tokens.at[:, 0].set(vocab.BOS_ID)
    tokens = tokens.at[:, 1 : fill + 1].set(body)
    seg = jnp.zeros((rows, t), jnp.int32).at[:, : fill + 1].set(1)
    pos = jnp.zeros((rows, t), jnp.int32).at[:, : fill + 1].set(
        jnp.arange(fill + 1)
    )
    return tokens, seg, pos


def test_causality_future_tokens_dont_change_past_hidden(params):
    tokens, seg, pos = mk_tokens(0, CFG.train_batch, 20)
    h1 = model.forward_hidden(CFG, params, tokens, seg, pos, False)
    tokens2 = tokens.at[:, 15].set(7)  # perturb position 15
    h2 = model.forward_hidden(CFG, params, tokens2, seg, pos, False)
    np.testing.assert_allclose(h1[:, :15], h2[:, :15], atol=1e-6)
    assert not np.allclose(h1[:, 15:21], h2[:, 15:21], atol=1e-6)


def test_packed_segments_are_isolated(params):
    """Two sequences packed in one row must produce the same hidden states
    as the same sequences in separate rows."""
    t = CFG.seq_len
    a = [vocab.BOS_ID, 5, 6, 7, 8]
    b = [vocab.BOS_ID, 9, 10, 11]
    packed = jnp.zeros((CFG.train_batch, t), jnp.int32)
    packed = packed.at[0, : len(a)].set(jnp.array(a))
    packed = packed.at[0, len(a) : len(a) + len(b)].set(jnp.array(b))
    seg = jnp.zeros((CFG.train_batch, t), jnp.int32)
    seg = seg.at[0, : len(a)].set(1).at[0, len(a) : len(a) + len(b)].set(2)
    pos = jnp.zeros((CFG.train_batch, t), jnp.int32)
    pos = pos.at[0, : len(a)].set(jnp.arange(len(a)))
    pos = pos.at[0, len(a) : len(a) + len(b)].set(jnp.arange(len(b)))
    h_packed = model.forward_hidden(CFG, params, packed, seg, pos, False)

    solo = jnp.zeros((CFG.train_batch, t), jnp.int32)
    solo = solo.at[0, : len(a)].set(jnp.array(a))
    solo = solo.at[1, : len(b)].set(jnp.array(b))
    seg_s = jnp.zeros((CFG.train_batch, t), jnp.int32)
    seg_s = seg_s.at[0, : len(a)].set(1).at[1, : len(b)].set(1)
    pos_s = jnp.zeros((CFG.train_batch, t), jnp.int32)
    pos_s = pos_s.at[0, : len(a)].set(jnp.arange(len(a)))
    pos_s = pos_s.at[1, : len(b)].set(jnp.arange(len(b)))
    h_solo = model.forward_hidden(CFG, params, solo, seg_s, pos_s, False)

    np.testing.assert_allclose(h_packed[0, : len(a)], h_solo[0, : len(a)], atol=1e-5)
    np.testing.assert_allclose(
        h_packed[0, len(a) : len(a) + len(b)], h_solo[1, : len(b)], atol=1e-5
    )


def test_decode_chain_matches_teacher_forced_score(params):
    """The decode graph's chosen-token logprobs must equal the score
    graph's teacher-forced logprobs for the same context — the IS-weight
    consistency Eq. 5 relies on."""
    forced = [5, 9, 12, 7, 4]
    bg = CFG.gen_batch
    kv = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    cur = jnp.full((bg,), vocab.BOS_ID, jnp.int32)
    gum = jnp.zeros((bg, CFG.vocab))
    lps = []
    for i, ftok in enumerate(forced):
        pos = jnp.full((bg,), i, jnp.int32)
        nt, lp, _, kv, _ = model.decode_step(
            CFG, params, kv, pos, cur,
            gum, jnp.full((bg,), ftok, jnp.int32), jnp.ones((bg,)),
            jnp.float32(1.0),
        )
        lps.append(float(lp[0]))
        cur = nt

    tokens, seg, pos = mk_tokens(0, CFG.train_batch, len(forced))
    tokens = tokens.at[:, 1 : len(forced) + 1].set(jnp.array(forced))
    lp_score, _ = model.score(CFG, params, tokens, seg, pos)
    for i in range(len(forced)):
        assert abs(lps[i] - float(lp_score[0, i])) < 2e-3, (i, lps[i], lp_score[0, i])


def test_decode_samples_argmax_with_zero_gumbel(params):
    bg = CFG.gen_batch
    kv = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    cur = jnp.full((bg,), vocab.BOS_ID, jnp.int32)
    nt, lp, lp_all, _, _ = model.decode_step(
        CFG, params, kv, jnp.zeros((bg,), jnp.int32), cur,
        jnp.zeros((bg, CFG.vocab)), jnp.zeros((bg,), jnp.int32),
        jnp.zeros((bg,)), jnp.float32(1.0),
    )
    np.testing.assert_array_equal(nt, jnp.argmax(lp_all, axis=-1))
    # chosen lp is the max logprob
    np.testing.assert_allclose(lp, jnp.max(lp_all, axis=-1), atol=1e-6)


def test_train_step_is_onpolicy_consistent(params):
    """behavior_lp from score => ESS = 1, KL = 0, and loss gradient flows."""
    tokens, seg, pos = mk_tokens(1, CFG.train_batch, 24)
    mask = jnp.zeros(tokens.shape).at[:, 0:23].set(1.0)
    blp, _ = model.score(CFG, params, tokens, seg, pos)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    p2, m2, v2, metrics = model.train_step(
        CFG, params, m, v, jnp.float32(1.0), tokens, seg, pos,
        blp, jnp.ones(tokens.shape), jnp.ones(tokens.shape),
        mask, jnp.float32(1e-3), jnp.float32(5.0), jnp.float32(0.0),
        jnp.float32(0.0),
    )
    names = model.METRIC_NAMES
    ess = float(metrics[names.index("ess")])
    kl = float(metrics[names.index("mean_kl")])
    assert abs(ess - 1.0) < 1e-3
    assert abs(kl) < 1e-4
    assert float(metrics[names.index("grad_norm")]) > 0.0
    # params moved
    assert float(jnp.sum(jnp.abs(p2[0] - params[0]))) > 0.0


def test_value_mode_uses_value_head(params):
    """adv_mode=1 trains the value head (Eq. 4's v_phi)."""
    tokens, seg, pos = mk_tokens(2, CFG.train_batch, 16)
    mask = jnp.zeros(tokens.shape).at[:, 0:15].set(1.0)
    blp, _ = model.score(CFG, params, tokens, seg, pos)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    vh_index = [n for n, _ in CFG.param_specs()].index("value_head")
    p2, _, _, _ = model.train_step(
        CFG, params, m, v, jnp.float32(1.0), tokens, seg, pos,
        blp, jnp.zeros(tokens.shape), jnp.ones(tokens.shape),
        mask, jnp.float32(1e-3), jnp.float32(5.0), jnp.float32(1.0),
        jnp.float32(0.5),
    )
    dv = float(jnp.sum(jnp.abs(p2[vh_index] - params[vh_index])))
    assert dv > 0.0, "value head must receive gradient in value mode"


def test_sft_reduces_loss(params):
    tokens, seg, pos = mk_tokens(3, CFG.train_batch, 30)
    mask = jnp.zeros(tokens.shape).at[:, 0:29].set(1.0)
    ps = list(params)
    m = [jnp.zeros_like(p) for p in ps]
    v = [jnp.zeros_like(p) for p in ps]
    losses = []
    for step in range(1, 7):
        ps, m, v, metrics = model.sft_step(
            CFG, ps, m, v, jnp.float32(step), tokens, seg, pos, mask,
            jnp.float32(1e-2),
        )
        losses.append(float(metrics[0]))
    assert losses[-1] < losses[0], losses


def test_score_full_distribution_normalizes(params):
    tokens, seg, pos = mk_tokens(4, CFG.train_batch, 10)
    lp, logdist = model.score_full(CFG, params, tokens, seg, pos)
    z = jnp.sum(jnp.exp(logdist), axis=-1)
    np.testing.assert_allclose(z, jnp.ones_like(z), atol=1e-4)
    # lp consistent with the distribution
    tgt = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((tokens.shape[0], 1), jnp.int32)], axis=1
    )
    picked = jnp.take_along_axis(logdist, tgt[..., None], axis=-1)[..., 0]
    np.testing.assert_allclose(lp[:, :-1], picked[:, :-1], atol=1e-6)
