"""L2 model invariants: causality, packing isolation, decode/score
consistency, training-step behaviour. All on the tiny config."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, vocab

CFG = configs.TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, 0)


def mk_tokens(seed, rows, fill):
    """Simple single-segment rows: BOS + `fill` random alphabet tokens."""
    t = CFG.seq_len
    key = jax.random.PRNGKey(seed)
    body = jax.random.randint(key, (rows, fill), 3, 40)
    tokens = jnp.zeros((rows, t), jnp.int32)
    tokens = tokens.at[:, 0].set(vocab.BOS_ID)
    tokens = tokens.at[:, 1 : fill + 1].set(body)
    seg = jnp.zeros((rows, t), jnp.int32).at[:, : fill + 1].set(1)
    pos = jnp.zeros((rows, t), jnp.int32).at[:, : fill + 1].set(
        jnp.arange(fill + 1)
    )
    return tokens, seg, pos


def test_causality_future_tokens_dont_change_past_hidden(params):
    tokens, seg, pos = mk_tokens(0, CFG.train_batch, 20)
    h1 = model.forward_hidden(CFG, params, tokens, seg, pos, False)
    tokens2 = tokens.at[:, 15].set(7)  # perturb position 15
    h2 = model.forward_hidden(CFG, params, tokens2, seg, pos, False)
    np.testing.assert_allclose(h1[:, :15], h2[:, :15], atol=1e-6)
    assert not np.allclose(h1[:, 15:21], h2[:, 15:21], atol=1e-6)


def test_packed_segments_are_isolated(params):
    """Two sequences packed in one row must produce the same hidden states
    as the same sequences in separate rows."""
    t = CFG.seq_len
    a = [vocab.BOS_ID, 5, 6, 7, 8]
    b = [vocab.BOS_ID, 9, 10, 11]
    packed = jnp.zeros((CFG.train_batch, t), jnp.int32)
    packed = packed.at[0, : len(a)].set(jnp.array(a))
    packed = packed.at[0, len(a) : len(a) + len(b)].set(jnp.array(b))
    seg = jnp.zeros((CFG.train_batch, t), jnp.int32)
    seg = seg.at[0, : len(a)].set(1).at[0, len(a) : len(a) + len(b)].set(2)
    pos = jnp.zeros((CFG.train_batch, t), jnp.int32)
    pos = pos.at[0, : len(a)].set(jnp.arange(len(a)))
    pos = pos.at[0, len(a) : len(a) + len(b)].set(jnp.arange(len(b)))
    h_packed = model.forward_hidden(CFG, params, packed, seg, pos, False)

    solo = jnp.zeros((CFG.train_batch, t), jnp.int32)
    solo = solo.at[0, : len(a)].set(jnp.array(a))
    solo = solo.at[1, : len(b)].set(jnp.array(b))
    seg_s = jnp.zeros((CFG.train_batch, t), jnp.int32)
    seg_s = seg_s.at[0, : len(a)].set(1).at[1, : len(b)].set(1)
    pos_s = jnp.zeros((CFG.train_batch, t), jnp.int32)
    pos_s = pos_s.at[0, : len(a)].set(jnp.arange(len(a)))
    pos_s = pos_s.at[1, : len(b)].set(jnp.arange(len(b)))
    h_solo = model.forward_hidden(CFG, params, solo, seg_s, pos_s, False)

    np.testing.assert_allclose(h_packed[0, : len(a)], h_solo[0, : len(a)], atol=1e-5)
    np.testing.assert_allclose(
        h_packed[0, len(a) : len(a) + len(b)], h_solo[1, : len(b)], atol=1e-5
    )


def test_decode_chain_matches_teacher_forced_score(params):
    """The decode graph's chosen-token logprobs must equal the score
    graph's teacher-forced logprobs for the same context — the IS-weight
    consistency Eq. 5 relies on."""
    forced = [5, 9, 12, 7, 4]
    bg = CFG.gen_batch
    kv = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    cur = jnp.full((bg,), vocab.BOS_ID, jnp.int32)
    gum = jnp.zeros((bg, CFG.vocab))
    lps = []
    for i, ftok in enumerate(forced):
        pos = jnp.full((bg,), i, jnp.int32)
        nt, lp, _, kv, _ = model.decode_step(
            CFG, params, kv, pos, cur,
            gum, jnp.full((bg,), ftok, jnp.int32), jnp.ones((bg,)),
            jnp.float32(1.0),
        )
        lps.append(float(lp[0]))
        cur = nt

    tokens, seg, pos = mk_tokens(0, CFG.train_batch, len(forced))
    tokens = tokens.at[:, 1 : len(forced) + 1].set(jnp.array(forced))
    lp_score, _ = model.score(CFG, params, tokens, seg, pos)
    for i in range(len(forced)):
        assert abs(lps[i] - float(lp_score[0, i])) < 2e-3, (i, lps[i], lp_score[0, i])


def test_decode_samples_argmax_with_zero_gumbel(params):
    bg = CFG.gen_batch
    kv = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    cur = jnp.full((bg,), vocab.BOS_ID, jnp.int32)
    nt, lp, lp_all, _, _ = model.decode_step(
        CFG, params, kv, jnp.zeros((bg,), jnp.int32), cur,
        jnp.zeros((bg, CFG.vocab)), jnp.zeros((bg,), jnp.int32),
        jnp.zeros((bg,)), jnp.float32(1.0),
    )
    np.testing.assert_array_equal(nt, jnp.argmax(lp_all, axis=-1))
    # chosen lp is the max logprob
    np.testing.assert_allclose(lp, jnp.max(lp_all, axis=-1), atol=1e-6)


# ---------------------------------------------------------------------------
# paged KV parity (the PR-8 acceptance claim: layout never changes tokens)
# ---------------------------------------------------------------------------

def _private_tables():
    """Block tables with zero sharing: row b owns physical blocks
    b*NB .. (b+1)*NB-1, trash block last. The worst-case layout the pool
    is sized for (model.kv_pool_shape)."""
    nb = model.blocks_per_row(CFG)
    b = CFG.gen_batch
    table = np.stack([np.arange(nb, dtype=np.int32) + r * nb for r in range(b)])
    trash = model.kv_pool_shape(CFG)[0] - 1
    return jnp.asarray(table), trash


def _no_copy(trash):
    """Fork lanes for a fork-free step: every row copies trash -> trash."""
    return jnp.full((CFG.gen_batch,), trash, jnp.int32)


def test_paged_decode_matches_dense_bitwise(params):
    """Free-running sampling chains through both decode graphs must agree
    bit-for-bit: same tokens, same behavior logprobs, same distributions.
    This is the correctness contract that lets `[kv] layout = paged` keep
    the dense artifact as a bit-identical fallback."""
    bg = CFG.gen_batch
    rng = np.random.default_rng(42)
    kv = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    pool = jnp.zeros(model.kv_pool_shape(CFG), jnp.float32)
    table, trash = _private_tables()
    nocopy = _no_copy(trash)
    cur_d = cur_p = jnp.full((bg,), vocab.BOS_ID, jnp.int32)
    ftok = jnp.zeros((bg,), jnp.int32)
    fmask = jnp.zeros((bg,), jnp.float32)
    temp = jnp.float32(1.0)
    for step in range(10):
        pos = jnp.full((bg,), step, jnp.int32)
        gum = jnp.asarray(rng.standard_normal((bg, CFG.vocab)).astype(np.float32))
        nt_d, lp_d, lpa_d, kv, _ = model.decode_step(
            CFG, params, kv, pos, cur_d, gum, ftok, fmask, temp
        )
        nt_p, lp_p, lpa_p, pool, _ = model.decode_step_paged(
            CFG, params, pool, table, nocopy, nocopy,
            pos, cur_p, gum, ftok, fmask, temp
        )
        np.testing.assert_array_equal(np.asarray(nt_d), np.asarray(nt_p))
        np.testing.assert_array_equal(np.asarray(lp_d), np.asarray(lp_p))
        np.testing.assert_array_equal(np.asarray(lpa_d), np.asarray(lpa_p))
        cur_d, cur_p = nt_d, nt_p


def test_paged_shared_prefix_fork_matches_dense(params):
    """Rows 0 and 1 physically share their prompt block (one device block,
    refcount 2); at the first divergent write the test performs the
    allocator's CoW fork through the copy_src/copy_dst lanes — a real
    device block copy — and the token stream must still match a dense run
    where each row always had its own private cache."""
    bg = CFG.gen_batch
    nb = model.blocks_per_row(CFG)
    rng = np.random.default_rng(7)
    prompt = [5, 9, 12, 7, 4, 11, 6]            # positions 0..6, one block
    assert len(prompt) <= CFG.kv_block_size

    kv = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    pool = jnp.zeros(model.kv_pool_shape(CFG), jnp.float32)
    trash = model.kv_pool_shape(CFG)[0] - 1
    nocopy = _no_copy(trash)
    # physical layout: block 0 is shared by rows 0+1 for logical block 0;
    # everything else private; block `fork_blk` stays free for the fork
    table = np.zeros((bg, nb), dtype=np.int32)
    nxt = 1
    for r in range(bg):
        for j in range(nb):
            if r in (0, 1) and j == 0:
                table[r, j] = 0
            else:
                table[r, j] = nxt
                nxt += 1
    fork_blk = nxt
    assert fork_blk < trash, "pool must keep a free block for the fork"
    table = jnp.asarray(table)

    cur_d = cur_p = jnp.full((bg,), vocab.BOS_ID, jnp.int32)
    temp = jnp.float32(1.0)
    forked = False
    for step in range(12):
        pos = jnp.full((bg,), step, jnp.int32)
        gum = jnp.asarray(rng.standard_normal((bg, CFG.vocab)).astype(np.float32))
        if step < len(prompt):
            # forced shared prompt: rows 0+1 scatter identical K/V into the
            # same physical block — the duplicate write is value-identical
            ftok = jnp.full((bg,), prompt[step], jnp.int32)
            fmask = jnp.ones((bg,), jnp.float32)
            csrc = cdst = nocopy
        else:
            ftok = jnp.zeros((bg,), jnp.int32)
            fmask = jnp.zeros((bg,), jnp.float32)
            if not forked:
                # first divergent feed: fork row 1's shared block before
                # its write lands (copy block 0 -> fork_blk, repoint)
                csrc = jnp.asarray(
                    np.where(np.arange(bg) == 1, 0, trash).astype(np.int32))
                cdst = jnp.asarray(
                    np.where(np.arange(bg) == 1, fork_blk, trash).astype(np.int32))
                table = table.at[1, 0].set(fork_blk)
                forked = True
            else:
                csrc = cdst = nocopy
        nt_d, lp_d, lpa_d, kv, _ = model.decode_step(
            CFG, params, kv, pos, cur_d, gum, ftok, fmask, temp
        )
        nt_p, lp_p, lpa_p, pool, _ = model.decode_step_paged(
            CFG, params, pool, table, csrc, cdst,
            pos, cur_p, gum, ftok, fmask, temp
        )
        np.testing.assert_array_equal(np.asarray(nt_d), np.asarray(nt_p))
        np.testing.assert_array_equal(np.asarray(lpa_d), np.asarray(lpa_p))
        cur_d, cur_p = nt_d, nt_p
    assert forked
    # the shared block really carried the prefix: row 0's dense timeline
    # for the prompt positions lives verbatim in physical block 0
    np.testing.assert_array_equal(
        np.asarray(pool[0, :, 0, : len(prompt)]),
        np.asarray(kv[:, 0, 0, : len(prompt)]),
    )
    # and the fork copy really diverged row 1 away from row 0's block
    assert not np.array_equal(
        np.asarray(pool[fork_blk, :, 0, : CFG.kv_block_size]),
        np.asarray(pool[0, :, 0, : CFG.kv_block_size]),
    )


def test_paged_kernel_matches_numpy_reference(params):
    """kernels.attention.paged_decode_attention == ref.paged_decode_attention
    on a random pool/table (independent of the model graphs)."""
    from compile.kernels import attention as attn_k
    from compile.kernels import ref

    rng = np.random.default_rng(3)
    n, _l, _two, bs, h, d = model.kv_pool_shape(CFG)
    nb = model.blocks_per_row(CFG)
    b = CFG.gen_batch
    kp = jnp.asarray(rng.standard_normal((n, bs, h, d)).astype(np.float32))
    vp = jnp.asarray(rng.standard_normal((n, bs, h, d)).astype(np.float32))
    q = jnp.asarray(rng.standard_normal((b, h, d)).astype(np.float32))
    table = jnp.asarray(
        np.stack([rng.permutation(n - 1)[:nb] for _ in range(b)]).astype(np.int32)
    )
    pos = jnp.asarray(rng.integers(0, nb * bs, size=(b,)).astype(np.int32))
    got = attn_k.paged_decode_attention(q, kp, vp, table, pos)
    want = ref.paged_decode_attention(q, kp, vp, table, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_train_step_is_onpolicy_consistent(params):
    """behavior_lp from score => ESS = 1, KL = 0, and loss gradient flows."""
    tokens, seg, pos = mk_tokens(1, CFG.train_batch, 24)
    mask = jnp.zeros(tokens.shape).at[:, 0:23].set(1.0)
    blp, _ = model.score(CFG, params, tokens, seg, pos)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    p2, m2, v2, metrics = model.train_step(
        CFG, params, m, v, jnp.float32(1.0), tokens, seg, pos,
        blp, jnp.ones(tokens.shape), jnp.ones(tokens.shape),
        mask, jnp.ones(tokens.shape), jnp.float32(1e-3), jnp.float32(5.0),
        jnp.float32(0.0), jnp.float32(0.0), jnp.float32(1.0),
    )
    names = model.METRIC_NAMES
    ess = float(metrics[names.index("ess")])
    kl = float(metrics[names.index("mean_kl")])
    assert abs(ess - 1.0) < 1e-3
    assert abs(kl) < 1e-4
    assert float(metrics[names.index("grad_norm")]) > 0.0
    # params moved
    assert float(jnp.sum(jnp.abs(p2[0] - params[0]))) > 0.0


def test_value_mode_uses_value_head(params):
    """adv_mode=1 trains the value head (Eq. 4's v_phi)."""
    tokens, seg, pos = mk_tokens(2, CFG.train_batch, 16)
    mask = jnp.zeros(tokens.shape).at[:, 0:15].set(1.0)
    blp, _ = model.score(CFG, params, tokens, seg, pos)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    vh_index = [n for n, _ in CFG.param_specs()].index("value_head")
    p2, _, _, _ = model.train_step(
        CFG, params, m, v, jnp.float32(1.0), tokens, seg, pos,
        blp, jnp.zeros(tokens.shape), jnp.ones(tokens.shape),
        mask, jnp.ones(tokens.shape), jnp.float32(1e-3), jnp.float32(5.0),
        jnp.float32(1.0), jnp.float32(0.5), jnp.float32(1.0),
    )
    dv = float(jnp.sum(jnp.abs(p2[vh_index] - params[vh_index])))
    assert dv > 0.0, "value head must receive gradient in value mode"


def test_sft_reduces_loss(params):
    tokens, seg, pos = mk_tokens(3, CFG.train_batch, 30)
    mask = jnp.zeros(tokens.shape).at[:, 0:29].set(1.0)
    ps = list(params)
    m = [jnp.zeros_like(p) for p in ps]
    v = [jnp.zeros_like(p) for p in ps]
    losses = []
    for step in range(1, 7):
        ps, m, v, metrics = model.sft_step(
            CFG, ps, m, v, jnp.float32(step), tokens, seg, pos, mask,
            jnp.float32(1e-2),
        )
        losses.append(float(metrics[0]))
    assert losses[-1] < losses[0], losses


def test_score_full_distribution_normalizes(params):
    tokens, seg, pos = mk_tokens(4, CFG.train_batch, 10)
    lp, logdist = model.score_full(CFG, params, tokens, seg, pos)
    z = jnp.sum(jnp.exp(logdist), axis=-1)
    np.testing.assert_allclose(z, jnp.ones_like(z), atol=1e-4)
    # lp consistent with the distribution
    tgt = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((tokens.shape[0], 1), jnp.int32)], axis=1
    )
    picked = jnp.take_along_axis(logdist, tgt[..., None], axis=-1)[..., 0]
    np.testing.assert_allclose(lp[:, :-1], picked[:, :-1], atol=1e-6)
