"""L2 model invariants: causality, packing isolation, decode/score
consistency, training-step behaviour. All on the tiny config."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, vocab

CFG = configs.TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, 0)


def mk_tokens(seed, rows, fill):
    """Simple single-segment rows: BOS + `fill` random alphabet tokens."""
    t = CFG.seq_len
    key = jax.random.PRNGKey(seed)
    body = jax.random.randint(key, (rows, fill), 3, 40)
    tokens = jnp.zeros((rows, t), jnp.int32)
    tokens = tokens.at[:, 0].set(vocab.BOS_ID)
    tokens = tokens.at[:, 1 : fill + 1].set(body)
    seg = jnp.zeros((rows, t), jnp.int32).at[:, : fill + 1].set(1)
    pos = jnp.zeros((rows, t), jnp.int32).at[:, : fill + 1].set(
        jnp.arange(fill + 1)
    )
    return tokens, seg, pos


def test_causality_future_tokens_dont_change_past_hidden(params):
    tokens, seg, pos = mk_tokens(0, CFG.train_batch, 20)
    h1 = model.forward_hidden(CFG, params, tokens, seg, pos, False)
    tokens2 = tokens.at[:, 15].set(7)  # perturb position 15
    h2 = model.forward_hidden(CFG, params, tokens2, seg, pos, False)
    np.testing.assert_allclose(h1[:, :15], h2[:, :15], atol=1e-6)
    assert not np.allclose(h1[:, 15:21], h2[:, 15:21], atol=1e-6)


def test_packed_segments_are_isolated(params):
    """Two sequences packed in one row must produce the same hidden states
    as the same sequences in separate rows."""
    t = CFG.seq_len
    a = [vocab.BOS_ID, 5, 6, 7, 8]
    b = [vocab.BOS_ID, 9, 10, 11]
    packed = jnp.zeros((CFG.train_batch, t), jnp.int32)
    packed = packed.at[0, : len(a)].set(jnp.array(a))
    packed = packed.at[0, len(a) : len(a) + len(b)].set(jnp.array(b))
    seg = jnp.zeros((CFG.train_batch, t), jnp.int32)
    seg = seg.at[0, : len(a)].set(1).at[0, len(a) : len(a) + len(b)].set(2)
    pos = jnp.zeros((CFG.train_batch, t), jnp.int32)
    pos = pos.at[0, : len(a)].set(jnp.arange(len(a)))
    pos = pos.at[0, len(a) : len(a) + len(b)].set(jnp.arange(len(b)))
    h_packed = model.forward_hidden(CFG, params, packed, seg, pos, False)

    solo = jnp.zeros((CFG.train_batch, t), jnp.int32)
    solo = solo.at[0, : len(a)].set(jnp.array(a))
    solo = solo.at[1, : len(b)].set(jnp.array(b))
    seg_s = jnp.zeros((CFG.train_batch, t), jnp.int32)
    seg_s = seg_s.at[0, : len(a)].set(1).at[1, : len(b)].set(1)
    pos_s = jnp.zeros((CFG.train_batch, t), jnp.int32)
    pos_s = pos_s.at[0, : len(a)].set(jnp.arange(len(a)))
    pos_s = pos_s.at[1, : len(b)].set(jnp.arange(len(b)))
    h_solo = model.forward_hidden(CFG, params, solo, seg_s, pos_s, False)

    np.testing.assert_allclose(h_packed[0, : len(a)], h_solo[0, : len(a)], atol=1e-5)
    np.testing.assert_allclose(
        h_packed[0, len(a) : len(a) + len(b)], h_solo[1, : len(b)], atol=1e-5
    )


def test_decode_chain_matches_teacher_forced_score(params):
    """The decode graph's chosen-token logprobs must equal the score
    graph's teacher-forced logprobs for the same context — the IS-weight
    consistency Eq. 5 relies on."""
    forced = [5, 9, 12, 7, 4]
    bg = CFG.gen_batch
    kv = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    cur = jnp.full((bg,), vocab.BOS_ID, jnp.int32)
    gum = jnp.zeros((bg, CFG.vocab))
    lps = []
    for i, ftok in enumerate(forced):
        pos = jnp.full((bg,), i, jnp.int32)
        nt, lp, _, kv, _ = model.decode_step(
            CFG, params, kv, pos, cur,
            gum, jnp.full((bg,), ftok, jnp.int32), jnp.ones((bg,)),
            jnp.float32(1.0),
        )
        lps.append(float(lp[0]))
        cur = nt

    tokens, seg, pos = mk_tokens(0, CFG.train_batch, len(forced))
    tokens = tokens.at[:, 1 : len(forced) + 1].set(jnp.array(forced))
    lp_score, _ = model.score(CFG, params, tokens, seg, pos)
    for i in range(len(forced)):
        assert abs(lps[i] - float(lp_score[0, i])) < 2e-3, (i, lps[i], lp_score[0, i])


def test_decode_samples_argmax_with_zero_gumbel(params):
    bg = CFG.gen_batch
    kv = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    cur = jnp.full((bg,), vocab.BOS_ID, jnp.int32)
    nt, lp, lp_all, _, _ = model.decode_step(
        CFG, params, kv, jnp.zeros((bg,), jnp.int32), cur,
        jnp.zeros((bg, CFG.vocab)), jnp.zeros((bg,), jnp.int32),
        jnp.zeros((bg,)), jnp.float32(1.0),
    )
    np.testing.assert_array_equal(nt, jnp.argmax(lp_all, axis=-1))
    # chosen lp is the max logprob
    np.testing.assert_allclose(lp, jnp.max(lp_all, axis=-1), atol=1e-6)


# ---------------------------------------------------------------------------
# paged KV parity (the PR-8 acceptance claim: layout never changes tokens)
# ---------------------------------------------------------------------------

def _private_tables():
    """Block tables with zero sharing: row b owns physical blocks
    b*NB .. (b+1)*NB-1, trash block last. The worst-case layout the pool
    is sized for (model.kv_pool_shape)."""
    nb = model.blocks_per_row(CFG)
    b = CFG.gen_batch
    table = np.stack([np.arange(nb, dtype=np.int32) + r * nb for r in range(b)])
    trash = model.kv_pool_shape(CFG)[0] - 1
    return jnp.asarray(table), trash


def _no_copy(trash):
    """Fork lanes for a fork-free step: every row copies trash -> trash."""
    return jnp.full((CFG.gen_batch,), trash, jnp.int32)


def test_paged_decode_matches_dense_bitwise(params):
    """Free-running sampling chains through both decode graphs must agree
    bit-for-bit: same tokens, same behavior logprobs, same distributions.
    This is the correctness contract that lets `[kv] layout = paged` keep
    the dense artifact as a bit-identical fallback."""
    bg = CFG.gen_batch
    rng = np.random.default_rng(42)
    kv = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    pool = jnp.zeros(model.kv_pool_shape(CFG), jnp.float32)
    table, trash = _private_tables()
    nocopy = _no_copy(trash)
    cur_d = cur_p = jnp.full((bg,), vocab.BOS_ID, jnp.int32)
    ftok = jnp.zeros((bg,), jnp.int32)
    fmask = jnp.zeros((bg,), jnp.float32)
    temp = jnp.float32(1.0)
    for step in range(10):
        pos = jnp.full((bg,), step, jnp.int32)
        gum = jnp.asarray(rng.standard_normal((bg, CFG.vocab)).astype(np.float32))
        nt_d, lp_d, lpa_d, kv, _ = model.decode_step(
            CFG, params, kv, pos, cur_d, gum, ftok, fmask, temp
        )
        nt_p, lp_p, lpa_p, pool, _ = model.decode_step_paged(
            CFG, params, pool, table, nocopy, nocopy,
            pos, cur_p, gum, ftok, fmask, temp
        )
        np.testing.assert_array_equal(np.asarray(nt_d), np.asarray(nt_p))
        np.testing.assert_array_equal(np.asarray(lp_d), np.asarray(lp_p))
        np.testing.assert_array_equal(np.asarray(lpa_d), np.asarray(lpa_p))
        cur_d, cur_p = nt_d, nt_p


def test_paged_shared_prefix_fork_matches_dense(params):
    """Rows 0 and 1 physically share their prompt block (one device block,
    refcount 2); at the first divergent write the test performs the
    allocator's CoW fork through the copy_src/copy_dst lanes — a real
    device block copy — and the token stream must still match a dense run
    where each row always had its own private cache."""
    bg = CFG.gen_batch
    nb = model.blocks_per_row(CFG)
    rng = np.random.default_rng(7)
    prompt = [5, 9, 12, 7, 4, 11, 6]            # positions 0..6, one block
    assert len(prompt) <= CFG.kv_block_size

    kv = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    pool = jnp.zeros(model.kv_pool_shape(CFG), jnp.float32)
    trash = model.kv_pool_shape(CFG)[0] - 1
    nocopy = _no_copy(trash)
    # physical layout: block 0 is shared by rows 0+1 for logical block 0;
    # everything else private; block `fork_blk` stays free for the fork
    table = np.zeros((bg, nb), dtype=np.int32)
    nxt = 1
    for r in range(bg):
        for j in range(nb):
            if r in (0, 1) and j == 0:
                table[r, j] = 0
            else:
                table[r, j] = nxt
                nxt += 1
    fork_blk = nxt
    assert fork_blk < trash, "pool must keep a free block for the fork"
    table = jnp.asarray(table)

    cur_d = cur_p = jnp.full((bg,), vocab.BOS_ID, jnp.int32)
    temp = jnp.float32(1.0)
    forked = False
    for step in range(12):
        pos = jnp.full((bg,), step, jnp.int32)
        gum = jnp.asarray(rng.standard_normal((bg, CFG.vocab)).astype(np.float32))
        if step < len(prompt):
            # forced shared prompt: rows 0+1 scatter identical K/V into the
            # same physical block — the duplicate write is value-identical
            ftok = jnp.full((bg,), prompt[step], jnp.int32)
            fmask = jnp.ones((bg,), jnp.float32)
            csrc = cdst = nocopy
        else:
            ftok = jnp.zeros((bg,), jnp.int32)
            fmask = jnp.zeros((bg,), jnp.float32)
            if not forked:
                # first divergent feed: fork row 1's shared block before
                # its write lands (copy block 0 -> fork_blk, repoint)
                csrc = jnp.asarray(
                    np.where(np.arange(bg) == 1, 0, trash).astype(np.int32))
                cdst = jnp.asarray(
                    np.where(np.arange(bg) == 1, fork_blk, trash).astype(np.int32))
                table = table.at[1, 0].set(fork_blk)
                forked = True
            else:
                csrc = cdst = nocopy
        nt_d, lp_d, lpa_d, kv, _ = model.decode_step(
            CFG, params, kv, pos, cur_d, gum, ftok, fmask, temp
        )
        nt_p, lp_p, lpa_p, pool, _ = model.decode_step_paged(
            CFG, params, pool, table, csrc, cdst,
            pos, cur_p, gum, ftok, fmask, temp
        )
        np.testing.assert_array_equal(np.asarray(nt_d), np.asarray(nt_p))
        np.testing.assert_array_equal(np.asarray(lpa_d), np.asarray(lpa_p))
        cur_d, cur_p = nt_d, nt_p
    assert forked
    # the shared block really carried the prefix: row 0's dense timeline
    # for the prompt positions lives verbatim in physical block 0
    np.testing.assert_array_equal(
        np.asarray(pool[0, :, 0, : len(prompt)]),
        np.asarray(kv[:, 0, 0, : len(prompt)]),
    )
    # and the fork copy really diverged row 1 away from row 0's block
    assert not np.array_equal(
        np.asarray(pool[fork_blk, :, 0, : CFG.kv_block_size]),
        np.asarray(pool[0, :, 0, : CFG.kv_block_size]),
    )


def test_paged_kernel_matches_numpy_reference(params):
    """kernels.attention.paged_decode_attention == ref.paged_decode_attention
    on a random pool/table (independent of the model graphs)."""
    from compile.kernels import attention as attn_k
    from compile.kernels import ref

    rng = np.random.default_rng(3)
    n, _l, _two, bs, h, d = model.kv_pool_shape(CFG)
    nb = model.blocks_per_row(CFG)
    b = CFG.gen_batch
    kp = jnp.asarray(rng.standard_normal((n, bs, h, d)).astype(np.float32))
    vp = jnp.asarray(rng.standard_normal((n, bs, h, d)).astype(np.float32))
    q = jnp.asarray(rng.standard_normal((b, h, d)).astype(np.float32))
    table = jnp.asarray(
        np.stack([rng.permutation(n - 1)[:nb] for _ in range(b)]).astype(np.int32)
    )
    pos = jnp.asarray(rng.integers(0, nb * bs, size=(b,)).astype(np.int32))
    got = attn_k.paged_decode_attention(q, kp, vp, table, pos)
    want = ref.paged_decode_attention(q, kp, vp, table, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# chunked prefill parity (PR-9: W forced tokens per dispatch never change
# tokens, logprobs, or the KV cache vs. token-at-a-time prefill)
# ---------------------------------------------------------------------------

# mixed prompt lengths: W (=8) divides none of them, rows finish prefill at
# different rounds (decode rides along while others still chunk), and the
# longest crosses a KV-block boundary during ingestion
_CHUNK_PROMPTS = [5, 11, 8, 16]


def _mk_streams(seed):
    rng = np.random.default_rng(seed)
    return [
        [vocab.BOS_ID] + [int(t) for t in rng.integers(3, 40, size=n)]
        for n in _CHUNK_PROMPTS
    ]


def _run_decode_sim(params, streams0, gum, n_gen):
    """Token-at-a-time engine simulator on decode_step: every round each
    row feeds one token; rows at the end of their stream sample (with the
    same fixed gumbel each round) and append. Returns per-row generated
    tokens / chosen lps / distributions, final kv, final positions."""
    bg = CFG.gen_batch
    temp = jnp.float32(1.0)
    streams = [list(s) for s in streams0]
    p = [0] * bg
    gen = [[] for _ in range(bg)]
    lps = [[] for _ in range(bg)]
    dists = [[] for _ in range(bg)]
    kv = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    while any(len(g) < n_gen for g in gen):
        pos = jnp.asarray(p, jnp.int32)
        cur = jnp.asarray([streams[r][p[r]] for r in range(bg)], jnp.int32)
        forced = [
            streams[r][p[r] + 1] if p[r] + 1 < len(streams[r]) else None
            for r in range(bg)
        ]
        ftok = jnp.asarray([f if f is not None else 0 for f in forced], jnp.int32)
        fmask = jnp.asarray(
            [0.0 if f is None else 1.0 for f in forced], jnp.float32)
        nt, lp, lpa, kv, _ = model.decode_step(
            CFG, params, kv, pos, cur, gum, ftok, fmask, temp)
        nt_h, lp_h, lpa_h = np.asarray(nt), np.asarray(lp), np.asarray(lpa)
        for r in range(bg):
            if forced[r] is None:
                streams[r].append(int(nt_h[r]))
                gen[r].append(int(nt_h[r]))
                lps[r].append(lp_h[r])
                dists[r].append(lpa_h[r])
            p[r] += 1
    return streams, gen, lps, dists, kv, p


def _run_chunk_sim(params, streams0, gum, n_gen, paged):
    """Chunked engine simulator: every round row r feeds
    n_r = min(W, len(stream_r) - p_r) tokens in one prefill_chunk
    dispatch; rows whose chunk reaches the stream end sample in the same
    dispatch. Decode rows ride along with n_r = 1."""
    bg = CFG.gen_batch
    w = CFG.prefill_chunk
    temp = jnp.float32(1.0)
    streams = [list(s) for s in streams0]
    p = [0] * bg
    gen = [[] for _ in range(bg)]
    lps = [[] for _ in range(bg)]
    dists = [[] for _ in range(bg)]
    if paged:
        cache = jnp.zeros(model.kv_pool_shape(CFG), jnp.float32)
        table, trash = _private_tables()
        nocopy = _no_copy(trash)
    else:
        cache = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    n_dispatch = 0
    while any(len(g) < n_gen for g in gen):
        n = [min(w, len(streams[r]) - p[r]) for r in range(bg)]
        toks = np.full((bg, w), vocab.PAD_ID, np.int32)
        for r in range(bg):
            toks[r, : n[r]] = streams[r][p[r] : p[r] + n[r]]
        forced = [
            streams[r][p[r] + n[r]] if p[r] + n[r] < len(streams[r]) else None
            for r in range(bg)
        ]
        ftok = jnp.asarray([f if f is not None else 0 for f in forced], jnp.int32)
        fmask = jnp.asarray(
            [0.0 if f is None else 1.0 for f in forced], jnp.float32)
        args = (jnp.asarray(p, jnp.int32), jnp.asarray(toks),
                jnp.asarray(n, jnp.int32), gum, ftok, fmask, temp)
        if paged:
            nt, lp, lpa, cache, _ = model.prefill_chunk_paged(
                CFG, params, cache, table, nocopy, nocopy, *args)
        else:
            nt, lp, lpa, cache, _ = model.prefill_chunk(CFG, params, cache, *args)
        n_dispatch += 1
        nt_h, lp_h, lpa_h = np.asarray(nt), np.asarray(lp), np.asarray(lpa)
        for r in range(bg):
            if forced[r] is None:
                streams[r].append(int(nt_h[r]))
                gen[r].append(int(nt_h[r]))
                lps[r].append(lp_h[r])
                dists[r].append(lpa_h[r])
            p[r] += n[r]
    return streams, gen, lps, dists, cache, p, n_dispatch


def test_prefill_chunk_matches_token_at_a_time_bitwise(params):
    """The PR-9 correctness contract: chunked prompt ingestion — W forced
    tokens per dispatch, remainders, decode rows riding along — yields
    bit-identical sampled tokens, chosen logprobs, full distributions AND
    KV contents vs. feeding the same streams one token at a time."""
    streams0 = _mk_streams(21)
    rng = np.random.default_rng(9)
    gum = jnp.asarray(
        rng.standard_normal((CFG.gen_batch, CFG.vocab)).astype(np.float32))
    n_gen = 3
    s_l, gen_l, lps_l, dists_l, kv_l, p_l = _run_decode_sim(
        params, streams0, gum, n_gen)
    s_c, gen_c, lps_c, dists_c, kv_c, p_c, nd = _run_chunk_sim(
        params, streams0, gum, n_gen, paged=False)
    for r in range(CFG.gen_batch):
        k = min(len(gen_l[r]), len(gen_c[r]))
        assert k >= n_gen
        assert gen_l[r][:k] == gen_c[r][:k], r
        np.testing.assert_array_equal(
            np.asarray(lps_l[r][:k]), np.asarray(lps_c[r][:k]))
        np.testing.assert_array_equal(
            np.asarray(dists_l[r][:k]), np.asarray(dists_c[r][:k]))
    # chunking really reduced dispatch count: the token-at-a-time sim uses
    # one dispatch per position of the slowest row
    assert nd < max(p_l)
    # KV contents agree bit-for-bit on every position both sims fed
    kv_l, kv_c = np.asarray(kv_l), np.asarray(kv_c)
    for r in range(CFG.gen_batch):
        ext = min(p_l[r], p_c[r])
        np.testing.assert_array_equal(
            kv_l[:, :, r, :ext], kv_c[:, :, r, :ext])


def test_prefill_chunk_paged_matches_dense_bitwise(params):
    """Chunked ingestion through the paged pool (block tables, trash
    parking) is bit-identical to chunked ingestion on the dense layout —
    the same contract the single-step graphs already honor."""
    streams0 = _mk_streams(22)
    rng = np.random.default_rng(10)
    gum = jnp.asarray(
        rng.standard_normal((CFG.gen_batch, CFG.vocab)).astype(np.float32))
    n_gen = 2
    _, gen_d, lps_d, dists_d, kv_d, p_d, _ = _run_chunk_sim(
        params, streams0, gum, n_gen, paged=False)
    _, gen_p, lps_p, dists_p, pool, p_p, _ = _run_chunk_sim(
        params, streams0, gum, n_gen, paged=True)
    assert p_d == p_p
    table, _trash = _private_tables()
    from compile.kernels import ref
    for r in range(CFG.gen_batch):
        assert gen_d[r] == gen_p[r], r
        np.testing.assert_array_equal(np.asarray(lps_d[r]), np.asarray(lps_p[r]))
        np.testing.assert_array_equal(
            np.asarray(dists_d[r]), np.asarray(dists_p[r]))
    # the densified pool carries the same timelines the dense kv does
    kv_d = np.asarray(kv_d)
    for l in range(CFG.n_layers):
        for plane in range(2):
            dense_view = np.asarray(
                ref.gather_kv_blocks(jnp.asarray(pool)[:, l, plane], table))
            for r in range(CFG.gen_batch):
                np.testing.assert_array_equal(
                    dense_view[r, : p_d[r]], kv_d[l, plane, r, : p_d[r]])


def test_prefill_chunk_boundary_crossing_and_trash_isolation(params):
    """One crafted chunk dispatch: rows 0/1 chunk positions 12..19 —
    crossing the kv_block_size=16 block boundary mid-chunk — row 2 is
    parked (vlen = 0), row 3 rides along as a plain decode row (vlen = 1,
    samples). Dense and paged must agree bitwise with each other and with
    the token-at-a-time continuation, and the parked row's physical
    blocks must come back untouched (inert scatters land in trash)."""
    bs = CFG.kv_block_size
    w = CFG.prefill_chunk
    assert 12 < bs < 12 + w, "chunk must straddle the block boundary"
    bg = CFG.gen_batch
    rng = np.random.default_rng(17)
    gum = jnp.asarray(rng.standard_normal((bg, CFG.vocab)).astype(np.float32))
    temp = jnp.float32(1.0)
    streams = [
        [vocab.BOS_ID] + [int(t) for t in rng.integers(3, 40, size=19)]
        for _ in range(bg)
    ]  # stream length 20: positions 0..19

    # shared 12-position prefix via the legacy graphs on both layouts
    kv = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    pool = jnp.zeros(model.kv_pool_shape(CFG), jnp.float32)
    table, trash = _private_tables()
    nocopy = _no_copy(trash)
    for p in range(12):
        pos = jnp.full((bg,), p, jnp.int32)
        cur = jnp.asarray([s[p] for s in streams], jnp.int32)
        ftok = jnp.asarray([s[p + 1] for s in streams], jnp.int32)
        fmask = jnp.ones((bg,), jnp.float32)
        _, _, _, kv, _ = model.decode_step(
            CFG, params, kv, pos, cur, gum, ftok, fmask, temp)
        _, _, _, pool, _ = model.decode_step_paged(
            CFG, params, pool, table, nocopy, nocopy,
            pos, cur, gum, ftok, fmask, temp)

    # the chunk dispatch: vlen [8, 8, 0, 1], start 12 (park for row 2)
    park = CFG.max_seq - 1
    vlen = [w, w, 0, 1]
    start = jnp.asarray([12, 12, park, 12], jnp.int32)
    toks = np.full((bg, w), vocab.PAD_ID, np.int32)
    for r, n in enumerate(vlen):
        toks[r, :n] = streams[r][12 : 12 + n]
    # rows 0/1 end at position 19 == stream end -> sample; row 3 samples
    # at 12; parked row 2 carries the idle-row forcing lanes (PAD)
    ftok = jnp.asarray([0, 0, vocab.PAD_ID, 0], jnp.int32)
    fmask = jnp.asarray([0.0, 0.0, 1.0, 0.0], jnp.float32)
    args = (start, jnp.asarray(toks), jnp.asarray(vlen, jnp.int32),
            gum, ftok, fmask, temp)
    pool_before = np.asarray(pool)
    nt_d, lp_d, lpa_d, kv, _ = model.prefill_chunk(CFG, params, kv, *args)
    nt_p, lp_p, lpa_p, pool, _ = model.prefill_chunk_paged(
        CFG, params, pool, table, nocopy, nocopy, *args)

    # dense == paged, bitwise, for the whole dispatch
    np.testing.assert_array_equal(np.asarray(nt_d), np.asarray(nt_p))
    np.testing.assert_array_equal(np.asarray(lp_d), np.asarray(lp_p))
    np.testing.assert_array_equal(np.asarray(lpa_d), np.asarray(lpa_p))

    # parked row 2's physical blocks are untouched: inert lanes write only
    # the trash block
    pool_after = np.asarray(pool)
    own = np.asarray(table)[2]
    np.testing.assert_array_equal(pool_after[own], pool_before[own])

    # == the token-at-a-time continuation: row 3's sample equals legacy
    # step 12; rows 0/1's samples equal legacy step 19
    kv_ref = jnp.zeros(model.kv_shape(CFG), jnp.float32)
    for p in range(12):
        pos = jnp.full((bg,), p, jnp.int32)
        cur = jnp.asarray([s[p] for s in streams], jnp.int32)
        ftok_l = jnp.asarray([s[p + 1] for s in streams], jnp.int32)
        _, _, _, kv_ref, _ = model.decode_step(
            CFG, params, kv_ref, pos, cur, gum, ftok_l,
            jnp.ones((bg,), jnp.float32), temp)
    row3_sample = None
    for p in range(12, 20):
        # rows 0/1 continue forced; rows 2/3 park after their work is done
        pos_v, cur_v, ftok_v, fmask_v = [], [], [], []
        for r in range(bg):
            if r in (0, 1):
                pos_v.append(p)
                cur_v.append(streams[r][p])
                last = p + 1 >= 20
                ftok_v.append(0 if last else streams[r][p + 1])
                fmask_v.append(0.0 if last else 1.0)
            elif r == 3 and p == 12:
                pos_v.append(p)
                cur_v.append(streams[r][p])
                ftok_v.append(0)
                fmask_v.append(0.0)
            else:  # parked
                pos_v.append(park)
                cur_v.append(vocab.PAD_ID)
                ftok_v.append(vocab.PAD_ID)
                fmask_v.append(1.0)
        nt_l, lp_l, lpa_l, kv_ref, _ = model.decode_step(
            CFG, params, kv_ref, jnp.asarray(pos_v, jnp.int32),
            jnp.asarray(cur_v, jnp.int32), gum,
            jnp.asarray(ftok_v, jnp.int32), jnp.asarray(fmask_v, jnp.float32),
            temp)
        if p == 12:
            row3_sample = (np.asarray(nt_l)[3], np.asarray(lp_l)[3],
                           np.asarray(lpa_l)[3])
    nt_d, lp_d, lpa_d = np.asarray(nt_d), np.asarray(lp_d), np.asarray(lpa_d)
    assert nt_d[3] == row3_sample[0]
    np.testing.assert_array_equal(lp_d[3], row3_sample[1])
    np.testing.assert_array_equal(lpa_d[3], row3_sample[2])
    for r in (0, 1):
        assert nt_d[r] == np.asarray(nt_l)[r]
        np.testing.assert_array_equal(lp_d[r], np.asarray(lp_l)[r])
        np.testing.assert_array_equal(lpa_d[r], np.asarray(lpa_l)[r])


def test_chunk_kernel_matches_numpy_reference(params):
    """kernels.attention.{chunk,paged_chunk}_decode_attention ==
    ref.{chunk,paged_chunk}_decode_attention on random data with
    arbitrary (even unordered) per-lane positions."""
    from compile.kernels import attention as attn_k
    from compile.kernels import ref

    rng = np.random.default_rng(5)
    n, _l, _two, bs, h, d = model.kv_pool_shape(CFG)
    nb = model.blocks_per_row(CFG)
    b = CFG.gen_batch
    w = CFG.prefill_chunk
    t = CFG.max_seq
    kc = jnp.asarray(rng.standard_normal((b, t, h, d)).astype(np.float32))
    vc = jnp.asarray(rng.standard_normal((b, t, h, d)).astype(np.float32))
    q = jnp.asarray(rng.standard_normal((b, w, h, d)).astype(np.float32))
    pos = jnp.asarray(rng.integers(0, t, size=(b, w)).astype(np.int32))
    got = attn_k.chunk_decode_attention(q, kc, vc, pos)
    want = ref.chunk_decode_attention(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    kp = jnp.asarray(rng.standard_normal((n, bs, h, d)).astype(np.float32))
    vp = jnp.asarray(rng.standard_normal((n, bs, h, d)).astype(np.float32))
    table = jnp.asarray(
        np.stack([rng.permutation(n - 1)[:nb] for _ in range(b)]).astype(np.int32)
    )
    posp = jnp.asarray(rng.integers(0, nb * bs, size=(b, w)).astype(np.int32))
    got = attn_k.paged_chunk_decode_attention(q, kp, vp, table, posp)
    want = ref.paged_chunk_decode_attention(q, kp, vp, table, posp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_train_step_is_onpolicy_consistent(params):
    """behavior_lp from score => ESS = 1, KL = 0, and loss gradient flows."""
    tokens, seg, pos = mk_tokens(1, CFG.train_batch, 24)
    mask = jnp.zeros(tokens.shape).at[:, 0:23].set(1.0)
    blp, _ = model.score(CFG, params, tokens, seg, pos)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    p2, m2, v2, metrics = model.train_step(
        CFG, params, m, v, jnp.float32(1.0), tokens, seg, pos,
        blp, jnp.ones(tokens.shape), jnp.ones(tokens.shape),
        mask, jnp.ones(tokens.shape), jnp.float32(1e-3), jnp.float32(5.0),
        jnp.float32(0.0), jnp.float32(0.0), jnp.float32(1.0),
    )
    names = model.METRIC_NAMES
    ess = float(metrics[names.index("ess")])
    kl = float(metrics[names.index("mean_kl")])
    assert abs(ess - 1.0) < 1e-3
    assert abs(kl) < 1e-4
    assert float(metrics[names.index("grad_norm")]) > 0.0
    # params moved
    assert float(jnp.sum(jnp.abs(p2[0] - params[0]))) > 0.0


def test_value_mode_uses_value_head(params):
    """adv_mode=1 trains the value head (Eq. 4's v_phi)."""
    tokens, seg, pos = mk_tokens(2, CFG.train_batch, 16)
    mask = jnp.zeros(tokens.shape).at[:, 0:15].set(1.0)
    blp, _ = model.score(CFG, params, tokens, seg, pos)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    vh_index = [n for n, _ in CFG.param_specs()].index("value_head")
    p2, _, _, _ = model.train_step(
        CFG, params, m, v, jnp.float32(1.0), tokens, seg, pos,
        blp, jnp.zeros(tokens.shape), jnp.ones(tokens.shape),
        mask, jnp.ones(tokens.shape), jnp.float32(1e-3), jnp.float32(5.0),
        jnp.float32(1.0), jnp.float32(0.5), jnp.float32(1.0),
    )
    dv = float(jnp.sum(jnp.abs(p2[vh_index] - params[vh_index])))
    assert dv > 0.0, "value head must receive gradient in value mode"


def test_sft_reduces_loss(params):
    tokens, seg, pos = mk_tokens(3, CFG.train_batch, 30)
    mask = jnp.zeros(tokens.shape).at[:, 0:29].set(1.0)
    ps = list(params)
    m = [jnp.zeros_like(p) for p in ps]
    v = [jnp.zeros_like(p) for p in ps]
    losses = []
    for step in range(1, 7):
        ps, m, v, metrics = model.sft_step(
            CFG, ps, m, v, jnp.float32(step), tokens, seg, pos, mask,
            jnp.float32(1e-2),
        )
        losses.append(float(metrics[0]))
    assert losses[-1] < losses[0], losses


def test_score_full_distribution_normalizes(params):
    tokens, seg, pos = mk_tokens(4, CFG.train_batch, 10)
    lp, logdist = model.score_full(CFG, params, tokens, seg, pos)
    z = jnp.sum(jnp.exp(logdist), axis=-1)
    np.testing.assert_allclose(z, jnp.ones_like(z), atol=1e-4)
    # lp consistent with the distribution
    tgt = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((tokens.shape[0], 1), jnp.int32)], axis=1
    )
    picked = jnp.take_along_axis(logdist, tgt[..., None], axis=-1)[..., 0]
    np.testing.assert_allclose(lp[:, :-1], picked[:, :-1], atol=1e-6)
