"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes/seeds; the custom-VJP backward of the fused loss
is additionally checked against jax.grad of the reference implementation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adam, attention, ref, reinforce_loss

SET = dict(max_examples=8, deadline=None)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    tq=st.sampled_from([32, 64, 96]),
    seed=st.integers(0, 2**16),
)
def test_flash_attention_matches_ref(b, h, d, tq, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, tq, h, d))
    k = jax.random.normal(ks[1], (b, tq, h, d))
    v = jax.random.normal(ks[2], (b, tq, h, d))
    # random packed segment structure incl. trailing padding
    lens = jax.random.randint(ks[3], (b, 3), 0, tq // 2)
    seg_rows = []
    for row in np.asarray(lens):
        ids = []
        for s, ln in enumerate(row):
            ids.extend([s + 1] * int(ln))
        ids = ids[:tq]
        ids += [0] * (tq - len(ids))
        seg_rows.append(ids)
    seg = jnp.asarray(seg_rows, jnp.int32)
    out = attention.flash_attention(q, k, v, seg)
    want = ref.causal_segment_attention(q, k, v, seg)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@settings(**SET)
@given(
    b=st.integers(1, 4),
    h=st.sampled_from([1, 2]),
    d=st.sampled_from([8, 32]),
    t=st.sampled_from([16, 96]),
    seed=st.integers(0, 2**16),
)
def test_decode_attention_matches_ref(b, h, d, t, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, t, h, d))
    vc = jax.random.normal(ks[2], (b, t, h, d))
    pos = jax.random.randint(ks[3], (b,), 0, t)
    out = attention.decode_attention(q, kc, vc, pos)
    want = ref.decode_attention(q, kc, vc, pos)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_decode_attention_ignores_future_cache():
    # entries beyond pos must not affect the output
    b, t, h, d = 2, 32, 2, 16
    q = rand(0, b, h, d)
    kc = rand(1, b, t, h, d)
    vc = rand(2, b, t, h, d)
    pos = jnp.array([5, 9], jnp.int32)
    out1 = attention.decode_attention(q, kc, vc, pos)
    kc2 = kc.at[:, 12:].set(99.0)
    vc2 = vc.at[:, 12:].set(-99.0)
    out2 = attention.decode_attention(q, kc2, vc2, pos)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


# ---------------------------------------------------------------------------
# fused IS-REINFORCE loss
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    b=st.integers(1, 3),
    t=st.sampled_from([32, 64]),
    d=st.sampled_from([16, 32]),
    clip=st.sampled_from([1.0, 5.0, 20.0]),
    seed=st.integers(0, 2**16),
)
def test_fused_loss_fwd_matches_ref(b, t, d, clip, seed):
    V = 64
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    h = jax.random.normal(ks[0], (b, t, d))
    e = jax.random.normal(ks[1], (V, d)) * 0.3
    tgt = jax.random.randint(ks[2], (b, t), 0, V)
    blp = -jnp.abs(jax.random.normal(ks[3], (b, t)))
    got = reinforce_loss.fused_loss(h, e, tgt, blp, jnp.float32(clip))
    want = ref.fused_loss_fwd(h, e, tgt, blp, clip)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=3e-5, rtol=3e-5)


@settings(**SET)
@given(seed=st.integers(0, 2**16), clip=st.sampled_from([1.0, 5.0]))
def test_fused_loss_bwd_matches_jax_grad_of_ref(seed, clip):
    b, t, d, V = 2, 32, 16, 64
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    h = jax.random.normal(ks[0], (b, t, d))
    e = jax.random.normal(ks[1], (V, d)) * 0.3
    tgt = jax.random.randint(ks[2], (b, t), 0, V)
    blp = -jnp.abs(jax.random.normal(ks[3], (b, t)))
    adv = jax.random.normal(ks[4], (b, t))
    mask = (jax.random.uniform(ks[5], (b, t)) > 0.3).astype(jnp.float32)

    def loss_kernel(h, e):
        lp, w, _ = reinforce_loss.fused_loss(h, e, tgt, blp, jnp.float32(clip))
        return jnp.sum(-w * adv * lp * mask)

    def loss_ref(h, e):
        lp, w, _ = ref.fused_loss_fwd(h, e, tgt, blp, clip)
        return jnp.sum(-w * adv * lp * mask)

    gk = jax.grad(loss_kernel, argnums=(0, 1))(h, e)
    gr = jax.grad(loss_ref, argnums=(0, 1))(h, e)
    np.testing.assert_allclose(gk[0], gr[0], atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(gk[1], gr[1], atol=5e-5, rtol=5e-5)


def test_is_weight_truncation_boundary():
    # ratio exactly at the clip: w == clip, and beyond: clipped
    d, V = 8, 64
    h = jnp.zeros((1, 32, d))
    e = jnp.zeros((V, d))
    tgt = jnp.zeros((1, 32), jnp.int32)
    # uniform logits -> lp = -log(V); choose blp so ratio = 10 > clip 5
    blp = jnp.full((1, 32), -jnp.log(V) - jnp.log(10.0))
    _, w, _ = reinforce_loss.fused_loss(h, e, tgt, blp, jnp.float32(5.0))
    np.testing.assert_allclose(w, 5.0, atol=1e-4)


# ---------------------------------------------------------------------------
# fused Adam
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    n=st.sampled_from([7, 64, 1000, 1024, 5000]),
    step=st.integers(1, 200),
    seed=st.integers(0, 2**16),
)
def test_adam_matches_ref(n, step, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    p = jax.random.normal(ks[0], (n,))
    m = jax.random.normal(ks[1], (n,)) * 0.1
    v = jnp.abs(jax.random.normal(ks[2], (n,))) * 0.01
    g = jax.random.normal(ks[3], (n,))
    got = adam.adam_update(p, m, v, g, jnp.float32(1e-3), jnp.float32(step))
    want = ref.adam_update(
        p, m, v, g, 1e-3, adam.BETA1, adam.BETA2, adam.EPS, float(step)
    )
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-5)


def test_adam_shapes_preserved():
    p = jnp.ones((3, 5, 7))
    z = jnp.zeros_like(p)
    p2, m2, v2 = adam.adam_update(p, z, z, jnp.ones_like(p),
                                  jnp.float32(0.1), jnp.float32(1))
    assert p2.shape == p.shape == m2.shape == v2.shape
    # step 1, m_hat = g, v_hat = g^2 -> update = lr * 1/(1+eps) ~ lr
    np.testing.assert_allclose(p2, p - 0.1, atol=1e-4)
