"""AOT path smoke tests: HLO text emission, manifest completeness and the
ABI conventions the rust runtime depends on."""

import json
import os

import jax
import pytest

from compile import aot, configs, model, vocab


def test_hlo_text_emission_roundtrip(tmp_path):
    """Lower the tiny init graph and check it is valid HLO text."""
    cfg = configs.TINY
    files = aot.lower_variant(cfg, str(tmp_path), only={"init"})
    assert files == {"init": "tiny_init.hlo.txt"}
    text = (tmp_path / "tiny_init.hlo.txt").read_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # the entry layout takes exactly one s32 scalar (the seed)
    assert "entry_computation_layout={(s32[])->" in text


def test_manifest_structure(tmp_path):
    cfg = configs.TINY
    files = {"tiny": {"init": "tiny_init.hlo.txt"}}
    manifest = aot.build_manifest([cfg], files)
    v = manifest["variants"]["tiny"]
    assert v["n_params"] == cfg.n_params()
    assert len(v["params"]) == len(cfg.param_specs())
    assert v["params"][0]["name"] == "embed"
    assert manifest["metric_names"] == model.METRIC_NAMES
    assert manifest["pad_id"] == vocab.PAD_ID
    # every graph has an input signature
    for g in ("init", "decode", "decode_paged", "prefill_chunk",
              "prefill_chunk_paged", "train", "sft", "score", "score_full"):
        assert g in v["inputs"], g
    # paged-pool geometry is recorded for the rust allocator
    assert v["kv_block_size"] == cfg.kv_block_size
    assert v["kv_blocks_per_row"] * cfg.kv_block_size == cfg.max_seq
    assert v["kv_pool_blocks"] == cfg.gen_batch * v["kv_blocks_per_row"] + 1
    # chunked-prefill width is recorded for the rust engine's gate
    assert v["prefill_chunk"] == cfg.prefill_chunk
    # every cache-carrying decode/prefill variant declares its donation
    P = len(cfg.param_specs())
    for g in ("decode", "decode_paged", "prefill_chunk",
              "prefill_chunk_paged"):
        assert v["aliases"][g] == {"param": P, "output": aot.DECODE_KV_OUT}
    # json-serializable
    json.dumps(manifest)


def test_decode_graphs_emit_input_output_alias(tmp_path):
    """The donated cache operand must surface as a real input_output_alias
    in the lowered HLO header — that is what turns the declared donation
    at `run_buffers_b` call sites into a true in-place update."""
    cfg = configs.TINY
    files = aot.lower_variant(cfg, str(tmp_path), only={"decode", "decode_paged"})
    P = len(cfg.param_specs())
    for g in ("decode", "decode_paged"):
        header = (tmp_path / files[g]).read_text().splitlines()[0]
        assert "input_output_alias" in header, g
        # output tuple index 3 (the returned cache) aliases the cache
        # operand at flat parameter index P
        assert f"{{{aot.DECODE_KV_OUT}}}: ({P}, {{}}, may-alias)" in header, (
            g, header)


def test_signatures_match_model_conventions():
    cfg = configs.TINY
    sigs = aot.graph_signatures(cfg)
    decode = {s[0]: s for s in sigs["decode"]}
    assert decode["kv"][1] == model.kv_shape(cfg)
    assert decode["gumbel"][1] == (cfg.gen_batch, cfg.vocab)
    paged = {s[0]: s for s in sigs["decode_paged"]}
    assert paged["kv_pool"][1] == model.kv_pool_shape(cfg)
    nb = model.blocks_per_row(cfg)
    assert paged["block_table"][1] == (cfg.gen_batch, nb)
    assert paged["block_table"][2] == "i32"
    assert paged["copy_src"][1] == paged["copy_dst"][1] == (cfg.gen_batch,)
    chunk = {s[0]: s for s in sigs["prefill_chunk"]}
    assert chunk["kv"][1] == model.kv_shape(cfg)
    assert chunk["chunk_toks"][1] == (cfg.gen_batch, cfg.prefill_chunk)
    assert chunk["start"][1] == chunk["vlen"][1] == (cfg.gen_batch,)
    cpaged = {s[0]: s for s in sigs["prefill_chunk_paged"]}
    assert cpaged["kv_pool"][1] == model.kv_pool_shape(cfg)
    assert cpaged["block_table"][1] == (cfg.gen_batch, nb)
    assert cpaged["chunk_toks"][1] == (cfg.gen_batch, cfg.prefill_chunk)
    # the paged pool covers exactly the dense capacity plus the trash block
    n, _l, _two, bs, _h, _d = paged["kv_pool"][1]
    assert nb * bs == cfg.max_seq
    assert n == cfg.gen_batch * nb + 1
    train = {s[0]: s for s in sigs["train"]}
    # per-token reward (packing-exact)
    assert train["reward"][1] == (cfg.train_batch, cfg.seq_len)
    assert train["behavior_lp"][2] == "f32"
    assert train["tokens"][2] == "i32"


def test_generated_artifacts_match_current_code():
    """If artifacts/ exists, its manifest must agree with configs.py —
    guards against stale artifacts after a model change."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert manifest["metric_names"] == model.METRIC_NAMES
    for name, cfg in configs.VARIANTS.items():
        v = manifest["variants"][name]
        assert v["n_params"] == cfg.n_params(), f"stale artifacts for {name}"
        assert v["seq_len"] == cfg.seq_len
        got = [(p["name"], tuple(p["shape"])) for p in v["params"]]
        assert got == [(n, tuple(s)) for n, s in cfg.param_specs()]


def test_vocab_table_stable():
    table = vocab.build_table()
    assert len(table) == vocab.V
    assert table[vocab.PAD_ID] == "<pad>"
    assert table[vocab.EOS_ID] == "<eos>"
    text = "q:12+34=\na:46\n"
    assert vocab.decode(vocab.encode(text)) == text
