#!/usr/bin/env bash
# Tier-1 verification for the PipelineRL reproduction:
#
#   cargo build --release && cargo test -q
#   cargo clippy --all-targets -- -D warnings   (when clippy is installed)
#   cargo fmt --check                           (when rustfmt is installed)
#
# Environment notes
# -----------------
# * The workspace builds against the vendored no-PJRT `xla` stub
#   (rust/vendor/xla), so all device-free code — broker, weight bus,
#   checkpoints, config, RL math, perf model, cluster simulator, chaos
#   harness, property tests — builds and tests everywhere.
# * Tests that need a real engine (PJRT + AOT artifacts) gate themselves
#   on `runtime::runtime_available()` and print `SKIP <name>: ...` when
#   the runtime is absent. To run them: point the `xla` dependency in
#   rust/Cargo.toml at the upstream xla-rs bindings and build the
#   artifacts with `python python/compile/aot.py`.
# * If no cargo toolchain exists at all (minimal containers), this script
#   reports the gap and exits 0 so the skip is explicit, not a crash.

set -euo pipefail
cd "$(dirname "$0")"

# Python decode-graph conformance first: it needs no cargo toolchain, so
# it runs even in containers where the rust half below is skipped. This
# is where the paged-KV acceptance claims live: bit-for-bit paged-vs-
# dense decode parity (incl. a shared-prefix CoW fork mid-sequence) and
# input_output_alias emission on the donated KV/pool operands.
# test_kernels.py is excluded here only because it needs hypothesis,
# which minimal containers lack; CI runs the full python suite.
if command -v python3 >/dev/null 2>&1 \
    && python3 -c "import jax, pytest" >/dev/null 2>&1; then
    echo "== tier1: python decode-graph parity (pytest) =="
    (cd python && python3 -m pytest tests/test_model.py tests/test_aot.py -q)
else
    echo "tier1: python3+jax+pytest not available; skipping python parity tests" >&2
fi

cd rust

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: SKIP — no cargo toolchain on PATH in this environment." >&2
    echo "tier1: install rustup/cargo to run: cargo build --release && cargo test -q" >&2
    exit 0
fi

# format first: the cheapest check gives the fastest feedback (CI also
# runs it as a dedicated unconditional step, see .github/workflows/ci.yml)
if command -v rustfmt >/dev/null 2>&1; then
    echo "== tier1: cargo fmt --check =="
    cargo fmt --check
else
    echo "tier1: rustfmt not installed; skipping format check" >&2
fi

echo "== tier1: cargo build --release =="
cargo build --release

echo "== tier1: cargo test -q =="
cargo test -q

# Golden-run conformance: re-run the determinism suite under distinct
# seeds (DETERMINISM_SEED) so a digest regression cannot hide behind one
# lucky seed. The suite includes the control-plane scenarios (pause
# windows, guardrail rollback, the stale-manifest negative control), so
# every seed also proves pause/rollback recovery is digest-clean.
# On a mismatch the failing seed + first diverging event are written to
# rust/target/determinism/, and guardrail trips leave their forensics
# reports under rust/target/control/ — CI uploads both directories as
# artifacts, so a red run ships its own replay recipe.
echo "== tier1: determinism conformance (x${DETERMINISM_REPEATS:-3}) =="
for i in $(seq 1 "${DETERMINISM_REPEATS:-3}"); do
    seed=$(( 0xD17E + i * 7919 ))
    echo "-- determinism pass $i/${DETERMINISM_REPEATS:-3} (DETERMINISM_SEED=$seed)"
    DETERMINISM_SEED=$seed cargo test -q --test determinism
done

# KV-memory bench: entirely device-free (paged allocator + park/resume
# bookkeeping), so unlike the engine benches it runs everywhere and
# appends its numbers (prefix-sharing savings, preempt->resume cost,
# coalesced vs serial replay counts) to rust/BENCH_kvmem.json on every
# tier-1 pass — the perf trajectory stays a diffable artifact.
echo "== tier1: cargo bench --bench kvmem =="
cargo bench --bench kvmem

# On-policyness bench: device-free mode x correction sweep (truncated-IS
# ESS vs lag, learning-curve shape under each publish cadence, autoscaler
# guard behavior) -> rust/BENCH_onpolicy.json. The acceptance artifact
# for the off-policyness dial: corrected runs must sustain deeper lag
# than uncorrected ones at equal learning-curve shape.
echo "== tier1: cargo bench --bench onpolicy =="
cargo bench --bench onpolicy

# Serving-gateway bench: device-free (Gateway over SimService), so it
# runs everywhere -> rust/BENCH_gateway.json. The SLO table for the QoS
# acceptance claim: interactive p50/p99 admission-to-first-token across
# burst multipliers (preemption on/off) plus the gateway's per-tick
# scheduling overhead. The hard assertions live in tests/gateway.rs
# (run by `cargo test` above); this step keeps the latency trajectory a
# diffable artifact.
echo "== tier1: cargo bench --bench gateway =="
cargo bench --bench gateway

# clippy over every target (benches/examples/tests included), warnings
# fatal — the lint policy lives in [workspace.lints] in rust/Cargo.toml.
# Toolchain is pinned via rust-toolchain.toml (components include clippy).
if cargo clippy --version >/dev/null 2>&1; then
    echo "== tier1: cargo clippy --all-targets -- -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "tier1: clippy not installed; skipping lint check" >&2
fi

echo "tier1: OK"
